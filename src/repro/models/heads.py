"""Head graphs: the model-zoo IR for residual / multi-branch digital heads.

``FPCAModelProgram.head`` started as a linear tuple of stage specs — enough
for the paper's sequential VWW-class classifier, but not for the zoo
(:mod:`repro.fpca.zoo`): residual joins, branch concats and detection
outputs need a *graph*.  :class:`HeadGraph` is that IR:

* a tuple of named :class:`Node`\\ s, each applying one op to one or more
  named inputs (``"input"`` is the implicit frontend output);
* validated at construction — unique names, defined references, acyclic
  (Kahn toposort), geometry checked per node with precise messages;
* signature-versioned like the chain specs (:meth:`HeadGraph._sig_entries`
  extends the model signature under a ``"head_graph"`` tag, so chain-head
  signatures stay byte-identical);
* lowered to pure-jnp ops from :mod:`repro.models.layers`
  (:meth:`HeadGraph.apply` is the numerics contract the fused executables
  trace, exactly like ``FPCAModelProgram.apply_head``).

Graph-only ops live here: :class:`AddSpec` (elementwise residual join),
:class:`ConcatSpec` (channel concat) and :class:`DetectSpec` (per-coarse-cell
class scores + box regression).  A graph whose output node is a
:class:`DetectSpec` makes the model a *detection* workload: its raw
``(gh, gw, n_classes + 4)`` maps are split into :class:`Detections` at the
user-facing boundaries (``CompiledModel.run`` / ``stream`` /
``run_segment``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.fpca.program import (
    ActivationSpec,
    ConvSpec,
    DenseSpec,
    PoolSpec,
    _apply_activation,
    _check_activation,
)

__all__ = [
    "AddSpec",
    "ConcatSpec",
    "DetectSpec",
    "Node",
    "HeadGraph",
    "Detections",
]

# Bump when the *meaning* of a graph signature entry changes (same contract
# as program._SIG_VERSION).
_GRAPH_SIG_VERSION = "repro.fpca.head_graph/1"

#: The implicit source node every graph reads: the frontend's SS-ADC counts
#: (scaled by ``input_scale``).  Reserved — no node may take this name.
INPUT = "input"


# ---------------------------------------------------------------------------
# Graph-only ops
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AddSpec:
    """Elementwise residual join: sums >= 2 same-shape inputs, then an
    optional activation (the classic post-add relu)."""

    activation: str | None = None

    def __post_init__(self) -> None:
        _check_activation(self.activation)

    def _sig(self) -> tuple:
        return ("add", self.activation or "")


@dataclasses.dataclass(frozen=True)
class ConcatSpec:
    """Channel-axis concat of >= 2 inputs with matching leading dims."""

    activation: str | None = None

    def __post_init__(self) -> None:
        _check_activation(self.activation)

    def _sig(self) -> tuple:
        return ("concat", self.activation or "")


@dataclasses.dataclass(frozen=True)
class DetectSpec:
    """Per-coarse-cell detection output: ``n_classes`` class scores plus 4
    box-regression channels per spatial cell of its input — a ``kernel`` x
    ``kernel`` SAME-padded stride-1 conv emitting ``(gh, gw, n_classes + 4)``
    raw maps.  A graph ending in a DetectSpec makes the model's
    ``output_kind`` ``"detections"``; :class:`Detections` splits the raw map.
    """

    n_classes: int
    kernel: int = 1

    def __post_init__(self) -> None:
        if self.n_classes < 1:
            raise ValueError("detect n_classes must be >= 1")
        if self.kernel < 1:
            raise ValueError("detect kernel must be >= 1")

    @property
    def out_channels(self) -> int:
        return int(self.n_classes) + 4

    def _sig(self) -> tuple:
        return ("detect", int(self.n_classes), int(self.kernel))


_CHAIN_OPS = (ConvSpec, PoolSpec, DenseSpec, ActivationSpec)
_JOIN_OPS = (AddSpec, ConcatSpec)
_PARAM_OPS = (ConvSpec, DenseSpec, DetectSpec)
_ALL_OPS = _CHAIN_OPS + _JOIN_OPS + (DetectSpec,)


@dataclasses.dataclass(frozen=True)
class Node:
    """One named graph stage: ``op`` applied to the values of ``inputs``.

    ``inputs`` name other nodes (or :data:`INPUT`).  Join ops
    (:class:`AddSpec` / :class:`ConcatSpec`) take >= 2 inputs; every other
    op takes exactly one.
    """

    name: str
    op: Any
    inputs: tuple[str, ...] = (INPUT,)

    def __post_init__(self) -> None:
        object.__setattr__(self, "inputs", tuple(self.inputs))
        if not self.name or not isinstance(self.name, str):
            raise ValueError("node name must be a non-empty string")
        if not isinstance(self.op, _ALL_OPS):
            raise TypeError(f"unknown head graph op {self.op!r}")
        if isinstance(self.op, _JOIN_OPS):
            if len(self.inputs) < 2:
                kind = "add" if isinstance(self.op, AddSpec) else "concat"
                raise ValueError(
                    f"node {self.name!r}: {kind} needs at least 2 inputs, "
                    f"got {len(self.inputs)}"
                )
        elif len(self.inputs) != 1:
            raise ValueError(
                f"node {self.name!r}: {type(self.op).__name__} takes exactly "
                f"1 input, got {len(self.inputs)}"
            )

    def _sig(self) -> tuple:
        return ("node", self.name, self.inputs, self.op._sig())


def _chain_out_shape(op: Any, cur: tuple[int, ...], where: str) -> tuple:
    """Output shape of one single-input op — the same geometry rules as
    ``FPCAModelProgram.head_shapes``, with node-name-prefixed errors."""
    if isinstance(op, ConvSpec):
        if len(cur) != 3:
            raise ValueError(
                f"{where}: conv needs a spatial (h, w, c) input, got shape "
                f"{cur}"
            )
        h, w, _ = cur
        if op.padding == "SAME":
            return (-(-h // op.stride), -(-w // op.stride), op.out_channels)
        if op.kernel > h or op.kernel > w:
            raise ValueError(
                f"{where}: conv kernel {op.kernel} exceeds input {h}x{w}"
            )
        return ((h - op.kernel) // op.stride + 1,
                (w - op.kernel) // op.stride + 1, op.out_channels)
    if isinstance(op, DetectSpec):
        if len(cur) != 3:
            raise ValueError(
                f"{where}: detect needs a spatial (h, w, c) input, got shape "
                f"{cur}"
            )
        return (cur[0], cur[1], op.out_channels)
    if isinstance(op, PoolSpec):
        if len(cur) != 3:
            raise ValueError(
                f"{where}: pool needs a spatial (h, w, c) input, got shape "
                f"{cur}"
            )
        h, w, c = cur
        if op.size > h or op.size > w:
            raise ValueError(
                f"{where}: pool size {op.size} exceeds input {h}x{w}"
            )
        s = op.size if op.stride is None else op.stride
        return ((h - op.size) // s + 1, (w - op.size) // s + 1, c)
    if isinstance(op, DenseSpec):
        return (op.features,)
    return tuple(cur)                       # ActivationSpec: shape-preserving


@dataclasses.dataclass(frozen=True)
class HeadGraph:
    """A validated DAG of head stages — the graph generalisation of the
    linear ``FPCAModelProgram.head`` tuple.

    Construction validates names / references / arity / acyclicity;
    :meth:`shapes` validates geometry against a concrete input shape (the
    frontend's ``out_shape``, checked by ``FPCAModelProgram.__post_init__``).
    The output node must be a :class:`DenseSpec` (class logits — the model
    stays a classifier) or a :class:`DetectSpec` (per-cell detections), so
    ``n_classes`` / ``output_kind`` are always well defined.

    Parameters are a dict keyed by node name (parameterized nodes only:
    conv / dense / detect), mirroring the chain head's one-dict-per-stage
    list; :meth:`init` / :meth:`bind` / :meth:`apply` are the graph
    counterparts of ``init_head`` / ``bind_head_params`` / ``apply_head``.
    """

    nodes: tuple
    output: str

    def __post_init__(self) -> None:
        object.__setattr__(self, "nodes", tuple(self.nodes))
        if not self.nodes:
            raise ValueError("HeadGraph needs at least one node")
        for n in self.nodes:
            if not isinstance(n, Node):
                raise TypeError(f"HeadGraph nodes must be Node instances, got {n!r}")
        seen: set[str] = set()
        for n in self.nodes:
            if n.name == INPUT:
                raise ValueError(
                    f"node name {INPUT!r} is reserved for the graph input"
                )
            if n.name in seen:
                raise ValueError(f"duplicate node name {n.name!r} in HeadGraph")
            seen.add(n.name)
        for n in self.nodes:
            for ref in n.inputs:
                if ref != INPUT and ref not in seen:
                    raise ValueError(
                        f"node {n.name!r} reads undefined input {ref!r}"
                    )
        if self.output not in seen:
            raise ValueError(
                f"output {self.output!r} is not a node in the graph"
            )
        if not isinstance(self._out_op, (DenseSpec, DetectSpec)):
            raise ValueError(
                "the graph output must be a DenseSpec (logits) or DetectSpec "
                "(detections) node"
            )
        self.toposort()                     # raises on cycles

    # -- structure -----------------------------------------------------------
    @property
    def _by_name(self) -> dict[str, Node]:
        by = self.__dict__.get("_by_name_cache")
        if by is None:
            by = {n.name: n for n in self.nodes}
            object.__setattr__(self, "_by_name_cache", by)
        return by

    @property
    def _out_op(self) -> Any:
        return self._by_name[self.output].op

    def toposort(self) -> tuple[Node, ...]:
        """Evaluation order (Kahn), deterministic by definition order."""
        order = self.__dict__.get("_topo_cache")
        if order is not None:
            return order
        deps = {
            n.name: {r for r in n.inputs if r != INPUT} for n in self.nodes
        }
        done: set[str] = set()
        out: list[Node] = []
        while len(done) < len(self.nodes):
            ready = [
                n for n in self.nodes
                if n.name not in done and not (deps[n.name] - done)
            ]
            if not ready:
                stuck = sorted(set(deps) - done)
                raise ValueError(f"HeadGraph has a cycle through nodes {stuck}")
            for n in ready:
                done.add(n.name)
                out.append(n)
        order = tuple(out)
        object.__setattr__(self, "_topo_cache", order)
        return order

    # -- geometry ------------------------------------------------------------
    def shapes(self, in_shape: tuple[int, ...]) -> dict[str, tuple[int, ...]]:
        """Per-node output shapes for a concrete input shape (validates join
        geometry with node-named errors)."""
        shapes: dict[str, tuple[int, ...]] = {
            INPUT: tuple(int(d) for d in in_shape)
        }
        for node in self.toposort():
            ins = [shapes[r] for r in node.inputs]
            op = node.op
            if isinstance(op, AddSpec):
                for s in ins[1:]:
                    if s != ins[0]:
                        raise ValueError(
                            f"node {node.name!r}: residual add needs matching "
                            f"input shapes, got {ins[0]} vs {s}"
                        )
                shapes[node.name] = ins[0]
            elif isinstance(op, ConcatSpec):
                lead = ins[0][:-1]
                for s in ins[1:]:
                    if len(s) != len(ins[0]) or s[:-1] != lead:
                        raise ValueError(
                            f"node {node.name!r}: concat needs matching "
                            f"leading dims, got {ins[0]} vs {s}"
                        )
                shapes[node.name] = lead + (sum(s[-1] for s in ins),)
            else:
                shapes[node.name] = _chain_out_shape(
                    op, ins[0], f"node {node.name!r}"
                )
        return shapes

    def out_shape(self, in_shape: tuple[int, ...]) -> tuple[int, ...]:
        return self.shapes(in_shape)[self.output]

    @property
    def output_kind(self) -> str:
        return (
            "detections" if isinstance(self._out_op, DetectSpec) else "logits"
        )

    @property
    def n_classes(self) -> int:
        op = self._out_op
        return int(op.n_classes if isinstance(op, DetectSpec) else op.features)

    # -- identity ------------------------------------------------------------
    def _sig_entries(self) -> tuple:
        """Versioned primitive entries for the model signature.  Node names,
        wiring and op specs are all compile-relevant; parameters are not."""
        return (
            (_GRAPH_SIG_VERSION,)
            + tuple(n._sig() for n in self.nodes)
            + (("output", self.output),)
        )

    # -- parameters ----------------------------------------------------------
    def _param_nodes(self) -> list[Node]:
        return [n for n in self.nodes if isinstance(n.op, _PARAM_OPS)]

    def _want_shapes(
        self, node: Node, shapes: dict[str, tuple[int, ...]]
    ) -> dict[str, tuple[int, ...]]:
        op, cur = node.op, shapes[node.inputs[0]]
        if isinstance(op, (ConvSpec, DetectSpec)):
            c_out = op.out_channels
            return {"w": (c_out, op.kernel, op.kernel, cur[-1]),
                    "b": (c_out,)}
        d_in = 1
        for d in cur:
            d_in *= int(d)
        return {"w": (d_in, op.features), "b": (op.features,)}

    def init(self, key: jax.Array, in_shape: tuple[int, ...]) -> dict:
        """Fresh parameters: ``{node_name: {"w": ..., "b": ...}}`` for the
        parameterized nodes."""
        from repro.models.layers import init_conv2d, init_linear

        shapes = self.shapes(in_shape)
        nodes = self._param_nodes()
        keys = jax.random.split(key, max(len(nodes), 1))
        params: dict[str, dict] = {}
        for k, node in zip(keys, nodes):
            cur = shapes[node.inputs[0]]
            op = node.op
            if isinstance(op, (ConvSpec, DetectSpec)):
                params[node.name] = init_conv2d(
                    k, cur[-1], op.out_channels, op.kernel
                )
            else:
                d_in = 1
                for d in cur:
                    d_in *= int(d)
                params[node.name] = init_linear(k, d_in, op.features)
        return params

    def bind(self, params: Any, in_shape: tuple[int, ...]) -> dict:
        """Validate + coerce a graph parameter dict for serving (f32), the
        graph counterpart of ``FPCAModelProgram.bind_head_params``."""
        import jax.numpy as jnp

        if not isinstance(params, dict):
            raise ValueError(
                "graph head parameters must be a dict keyed by node name, "
                f"got {type(params).__name__}"
            )
        bound = {
            name: jax.tree_util.tree_map(
                lambda a: jnp.asarray(a, jnp.float32), dict(p)
            )
            for name, p in params.items()
        }
        want_names = {n.name for n in self._param_nodes()}
        if set(bound) != want_names:
            raise ValueError(
                f"graph head parameters keyed {sorted(bound)} do not match "
                f"parameterized nodes {sorted(want_names)}"
            )
        shapes = self.shapes(in_shape)
        for node in self._param_nodes():
            want = self._want_shapes(node, shapes)
            got = {k: tuple(v.shape) for k, v in bound[node.name].items()}
            if got != want:
                raise ValueError(
                    f"head node {node.name!r} ({type(node.op).__name__}): "
                    f"parameter shapes {got} do not match expected {want}"
                )
        return bound

    def apply(self, params: Any, x):
        """Evaluate the graph on a batch-leading input ``(b, h, w, c)`` —
        pure jnp ops, the numerics contract the fused executables trace.
        An unbatched ``(h, w, c)`` map is accepted too (the segment-seeding
        path feeds single effective maps, matching the chain-head MLPs
        which flatten either way)."""
        import jax.numpy as jnp

        from repro.models.layers import (
            avg_pool2d, conv2d, linear, max_pool2d,
        )

        if x.ndim == 3:
            return self.apply(params, x[None])[0]
        values: dict[str, Any] = {INPUT: x}
        for node in self.toposort():
            op = node.op
            ins = [values[r] for r in node.inputs]
            if isinstance(op, ConvSpec):
                y = _apply_activation(
                    op.activation,
                    conv2d(params[node.name], ins[0], op.stride, op.padding),
                )
            elif isinstance(op, DetectSpec):
                y = conv2d(params[node.name], ins[0], 1, "SAME")
            elif isinstance(op, PoolSpec):
                pool = max_pool2d if op.kind == "max" else avg_pool2d
                y = pool(ins[0], op.size, op.stride)
            elif isinstance(op, DenseSpec):
                v = ins[0]
                if v.ndim > 2:
                    v = v.reshape(v.shape[0], -1)
                y = _apply_activation(op.activation, linear(params[node.name], v))
            elif isinstance(op, AddSpec):
                y = ins[0]
                for v in ins[1:]:
                    y = y + v
                y = _apply_activation(op.activation, y)
            elif isinstance(op, ConcatSpec):
                y = _apply_activation(
                    op.activation, jnp.concatenate(ins, axis=-1)
                )
            else:                           # ActivationSpec
                y = _apply_activation(op.fn, ins[0])
            values[node.name] = y
        return values[self.output]


# ---------------------------------------------------------------------------
# Detection output struct
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Detections:
    """Per-coarse-cell detections: class ``scores`` ``(..., gh, gw, C)`` and
    ``boxes`` ``(..., gh, gw, 4)``, split from one raw :class:`DetectSpec`
    map.  Holds whatever array type it was built from (device arrays stay
    lazy); host-side helpers realise on demand."""

    scores: Any
    boxes: Any

    @classmethod
    def from_raw(cls, raw, n_classes: int) -> "Detections":
        n = int(n_classes)
        if raw.shape[-1] != n + 4:
            raise ValueError(
                f"raw detection map has {raw.shape[-1]} channels, expected "
                f"n_classes + 4 = {n + 4}"
            )
        return cls(scores=raw[..., :n], boxes=raw[..., n:])

    @property
    def n_classes(self) -> int:
        return int(self.scores.shape[-1])

    @property
    def grid_shape(self) -> tuple[int, int]:
        return (int(self.scores.shape[-3]), int(self.scores.shape[-2]))

    def class_map(self) -> np.ndarray:
        """Argmax class index per cell, realised to host."""
        return np.argmax(np.asarray(self.scores), axis=-1)

    def top_k(self, k: int = 5) -> list[dict]:
        """Best ``k`` cells of an unbatched map by max class score: a list of
        ``{"cell": (gy, gx), "class": int, "score": float, "box": [4]}``."""
        s = np.asarray(self.scores)
        b = np.asarray(self.boxes)
        if s.ndim != 3:
            raise ValueError(
                f"top_k expects an unbatched (gh, gw, C) detection map, got "
                f"shape {s.shape}"
            )
        best = s.max(axis=-1)
        cls_idx = s.argmax(axis=-1)
        gw = best.shape[1]
        flat = best.ravel()
        order = np.argsort(flat)[::-1][: int(k)]
        boxes = b.reshape(-1, 4)
        return [
            {
                "cell": (int(i // gw), int(i % gw)),
                "class": int(cls_idx.ravel()[i]),
                "score": float(flat[i]),
                "box": [float(v) for v in boxes[i]],
            }
            for i in order
        ]
