"""JAX version compatibility layer.

The repo targets the modern sharding API (``jax.sharding.set_mesh`` /
``get_abstract_mesh`` / ``AxisType``, dict-valued ``Compiled.cost_analysis``),
but must also run on jax 0.4.x where none of those exist.  Everything that
touches a version-dependent surface goes through here so the rest of the
codebase stays on one idiom.

Shims provided:

* :func:`get_abstract_mesh` — the ambient mesh seen at trace time, or ``None``
  (on 0.4.x this is the legacy ``thread_resources`` physical mesh set by the
  ``with mesh:`` / :func:`set_mesh` context);
* :func:`set_mesh` — context manager installing an ambient mesh for in-graph
  sharding constraints (``jax.sharding.set_mesh`` when available, the legacy
  ``Mesh.__enter__`` context otherwise);
* :func:`make_mesh` — ``jax.make_mesh`` with ``axis_types`` only where the
  installed jax knows about ``AxisType``;
* :func:`cost_analysis_dict` — normalises ``Compiled.cost_analysis()`` (a
  one-element list on 0.4.x, a flat dict on newer jax) to a dict.
"""

from __future__ import annotations

import contextlib
from typing import Any, Iterator

import jax

__all__ = ["get_abstract_mesh", "set_mesh", "make_mesh", "cost_analysis_dict"]

_HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")
_HAS_SET_MESH = hasattr(jax.sharding, "set_mesh")
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def get_abstract_mesh():
    """Ambient mesh during tracing, or ``None`` when no mesh is installed.

    Callers only rely on ``.empty`` / ``.axis_names``, which both the modern
    AbstractMesh and the legacy physical Mesh expose.
    """
    if _HAS_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    from jax.interpreters import pxla

    return pxla.thread_resources.env.physical_mesh


@contextlib.contextmanager
def set_mesh(mesh: jax.sharding.Mesh) -> Iterator[jax.sharding.Mesh]:
    """Install ``mesh`` as the ambient mesh for in-graph sharding constraints."""
    if _HAS_SET_MESH:
        with jax.sharding.set_mesh(mesh):
            yield mesh
    else:
        # legacy: Mesh is itself a context manager feeding thread_resources
        with mesh:
            yield mesh


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def cost_analysis_dict(compiled: Any) -> dict:
    """``Compiled.cost_analysis()`` as a dict on every supported jax."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return dict(cost)
