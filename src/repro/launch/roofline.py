"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), all in seconds (DESIGN.md §5 /
EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = wire_bytes_per_device / ICI_link_bandwidth

``cost_analysis`` of the partitioned module is already per-device.
Collective bytes are NOT in cost_analysis: we parse the optimized HLO
(``compiled.as_text()``) and charge each collective its ring-algorithm wire
traffic (group size from ``replica_groups``):

    all-gather        : out_bytes * (n-1)/n
    reduce-scatter    : out_bytes * (n-1)          (out is the shard)
    all-reduce        : 2 * bytes * (n-1)/n        (RS + AG)
    all-to-all        : bytes * (n-1)/n
    collective-permute: bytes

Hardware model (TPU v5e class, per assignment): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

__all__ = ["HW", "collective_bytes", "roofline_terms", "summarize_cell"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 / chip
    hbm_bw: float = 819e9           # B/s
    ici_bw: float = 50e9            # B/s per link (assignment constant)


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(?P<dtype>\w+)\[(?P<shape>[\d,]*)\][^=]*=\s*(?P<op>all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute)(?:-start)?\("
)
_TUPLE_COLL_RE = re.compile(
    r"=\s*\((?P<tuple>[^)]*)\)\s*(?P<op>all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\("
)
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")


def _shape_bytes(dtype: str, shape: str) -> float:
    el = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    if shape:
        for d in shape.split(","):
            n *= int(d)
    return float(el * n)


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return world


def _wire_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op == "all-gather":
        return (n - 1) / n
    if op == "reduce-scatter":
        return float(n - 1)
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0  # collective-permute


def collective_bytes(hlo_text: str, world: int) -> dict[str, Any]:
    """Per-device wire bytes by collective op, from optimized HLO text."""
    per_op: dict[str, dict[str, float]] = {}
    for line in hlo_text.splitlines():
        if "replica_groups" not in line:
            continue
        m = _COLL_RE.search(line)
        entries = []
        if m:
            entries.append((m.group("op"), _shape_bytes(m.group("dtype"), m.group("shape"))))
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if mt:
                tup = mt.group("tuple")
                total = 0.0
                for dt, shp in re.findall(r"(\w+)\[([\d,]*)\]", tup):
                    total += _shape_bytes(dt, shp)
                # tuple of (operand..., result...): charge result half
                entries.append((mt.group("op"), total / 2.0))
        for op, bytes_ in entries:
            n = _group_size(line, world)
            wire = bytes_ * _wire_factor(op, n)
            d = per_op.setdefault(op, {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
            d["count"] += 1
            d["bytes"] += bytes_
            d["wire_bytes"] += wire
    total_wire = sum(d["wire_bytes"] for d in per_op.values())
    return {"per_op": per_op, "total_wire_bytes": total_wire}


def roofline_terms(
    flops: float, bytes_accessed: float, wire_bytes: float, hw: HW = HW()
) -> dict[str, float]:
    terms = {
        "compute_s": flops / hw.peak_flops,
        "memory_s": bytes_accessed / hw.hbm_bw,
        "collective_s": wire_bytes / hw.ici_bw,
    }
    terms["dominant"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    terms["bound_s"] = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    return terms


def summarize_cell(
    compiled, cfg, shape, world: int, hw: HW = HW()
) -> dict[str, Any]:
    """Full §Roofline record for one compiled cell.

    FLOPs/bytes/collectives come from the while-aware HLO analyzer
    (:mod:`repro.launch.hlo_analysis`): XLA's ``cost_analysis`` counts each
    scan body once, which under-reports a scanned-layers transformer by the
    trip count — both raw views are recorded.
    """
    from repro.compat import cost_analysis_dict
    from repro.launch.hlo_analysis import analyze_hlo

    cost = cost_analysis_dict(compiled)
    mem = compiled.memory_analysis()
    hlo = analyze_hlo(compiled.as_text(), world)
    flops = float(hlo.flops)
    bytes_accessed = float(hlo.bytes_proxy)
    colls = {
        "per_op": hlo.collectives,
        "total_wire_bytes": hlo.wire_bytes,
        "n_whiles": hlo.n_whiles,
        "unknown_trip_whiles": hlo.unknown_trip_whiles,
    }
    terms = roofline_terms(flops, bytes_accessed, colls["total_wire_bytes"], hw)

    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:  # decode: one token per sequence per step
        tokens = shape.global_batch
        model_flops = 2.0 * n_active * tokens

    hlo_flops_global = flops * world
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    mfu_bound = model_flops / (world * hw.peak_flops * terms["bound_s"]) if terms["bound_s"] else 0.0
    return {
        "arch": cfg.name,
        "shape": shape.name,
        "world": world,
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collectives": colls,
        "terms": terms,
        "model_flops": model_flops,
        "useful_flop_ratio": useful,
        "roofline_mfu": mfu_bound,
        "xla_cost_analysis_raw": {
            "flops_body_once": float(cost.get("flops", 0.0)),
            "bytes_body_once": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
    }
