import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell on the production meshes, prove memory fits, and dump roofline raw
material.  MUST be run as a module entrypoint:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The two lines above this docstring run before ANY other import (jax locks
the device count on first init); nothing else in the repo sets XLA_FLAGS.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro import compat
from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.launch.cells import CellPlan, build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import summarize_cell
from repro.launch.sharding import ShardingPolicy

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_fpca_cell(
    shape_name: str, multi_pod: bool, *,
    fuse_phases: bool = False, bf16: bool = False, row_shard: bool = False,
) -> dict:
    """Paper-representative cell: the FPCA frontend at production scale."""
    from repro.core.curvefit import fit_bucket_model
    from repro.launch.fpca_cell import FPCA_SHAPES, build_fpca_cell
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.roofline import HW, roofline_terms

    shape = FPCA_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = fit_bucket_model()
    t0 = time.time()
    import jax.numpy as jnp

    with compat.set_mesh(mesh):
        jitted, args, info = build_fpca_cell(
            shape, mesh, model,
            fuse_phases=fuse_phases,
            compute_dtype=jnp.bfloat16 if bf16 else None,
            row_shard=row_shard,
        )
        compiled = jitted.lower(*args).compile()
    t_compile = time.time() - t0
    hlo = analyze_hlo(compiled.as_text(), mesh.size)
    terms = roofline_terms(hlo.flops, hlo.bytes_proxy, hlo.wire_bytes)
    mem = compiled.memory_analysis()
    model_flops = info.model_flops()
    hw = HW()
    print(mem)
    return {
        "arch": "fpca-frontend",
        "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "world": mesh.size,
        "compile_s": round(t_compile, 2),
        "flops_per_device": hlo.flops,
        "bytes_per_device": hlo.bytes_proxy,
        "collectives": {
            "per_op": hlo.collectives,
            "total_wire_bytes": hlo.wire_bytes,
            "n_whiles": hlo.n_whiles,
            "unknown_trip_whiles": hlo.unknown_trip_whiles,
        },
        "terms": terms,
        "model_flops": model_flops,
        "useful_flop_ratio": model_flops / (hlo.flops * mesh.size) if hlo.flops else 0.0,
        "roofline_mfu": (
            model_flops / (mesh.size * hw.peak_flops * terms["bound_s"])
            if terms["bound_s"] else 0.0
        ),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
    }


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, plan: CellPlan,
    cfg_overrides: dict | None = None,
) -> dict:
    import dataclasses as _dc

    cfg = ARCHS[arch]
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    world = mesh.size
    t0 = time.time()
    # set_mesh: in-graph sharding constraints (e.g. the vocab reshard in
    # layers.unembed) need the ambient abstract mesh during tracing.
    with compat.set_mesh(mesh):
        jitted, args = build_cell(cfg, shape, mesh, plan)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    rec = summarize_cell(compiled, cfg, shape, world)
    rec.update(
        mesh="multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        plan={
            "remat": plan.remat,
            "n_micro": plan.n_micro,
            "fsdp": plan.policy.fsdp,
            "tp": plan.policy.tp,
            "expert_parallel": plan.policy.expert_parallel,
        },
    )
    print(compiled.memory_analysis())
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    from repro.launch.fpca_cell import FPCA_SHAPES

    ap.add_argument(
        "--arch", choices=sorted(ARCHS) + ["fpca-frontend"], help="single architecture"
    )
    ap.add_argument("--shape", choices=sorted(SHAPES) + sorted(FPCA_SHAPES), help="single shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="run the full matrix")
    ap.add_argument("--tag", default="baseline", help="artifact subdirectory")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--n-micro", type=int, default=0)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=0.0, help="override MoE capacity")
    ap.add_argument("--block-k", type=int, default=0, help="override flash KV block")
    ap.add_argument("--no-vocab-shard", action="store_true", help="disable logits vocab reshard")
    ap.add_argument("--moe-local-dispatch", action="store_true", help="per-sequence expert routing")
    ap.add_argument("--fpca-fuse", action="store_true", help="fpca cell: fuse pos/neg phases")
    ap.add_argument("--fpca-bf16", action="store_true", help="fpca cell: bf16 operands")
    ap.add_argument("--fpca-rowshard", action="store_true", help="fpca cell: shard image rows over model")
    ap.add_argument("--no-tp", action="store_true")
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--no-expert-tp", action="store_true", help="replicate expert ff at use")
    ap.add_argument("--force", action="store_true", help="recompute existing artifacts")
    args = ap.parse_args()

    plan = CellPlan(
        policy=ShardingPolicy(
            fsdp=not args.no_fsdp,
            tp=not args.no_tp,
            expert_parallel=args.expert_parallel,
            expert_tp=not args.no_expert_tp,
        ),
        remat=args.remat,
        n_micro=args.n_micro,
    )
    archs = [args.arch] if args.arch else sorted(ARCHS)
    if args.arch == "fpca-frontend":
        shapes = [args.shape] if args.shape else sorted(FPCA_SHAPES)
    else:
        shapes = [args.shape] if args.shape else sorted(SHAPES)
    if args.all and not args.arch:
        archs = archs + ["fpca-frontend"]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if not (args.all or args.arch):
        ap.error("pass --all or --arch")

    out_dir = ARTIFACTS / args.tag
    out_dir.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        if args.shape:
            arch_shapes = [args.shape]
        else:
            arch_shapes = sorted(FPCA_SHAPES) if arch == "fpca-frontend" else shapes
        for shape_name in arch_shapes:
            for multi in meshes:
                mesh_tag = "multi" if multi else "single"
                path = out_dir / f"{arch}__{shape_name}__{mesh_tag}.json"
                if path.exists() and not args.force:
                    print(f"[skip existing] {path.name}")
                    continue
                label = f"{arch} x {shape_name} x {mesh_tag}"
                print(f"=== {label} ===", flush=True)
                try:
                    if arch == "fpca-frontend":
                        rec = run_fpca_cell(
                            shape_name, multi,
                            fuse_phases=args.fpca_fuse, bf16=args.fpca_bf16,
                            row_shard=args.fpca_rowshard,
                        )
                    else:
                        overrides = {}
                        if args.capacity_factor:
                            overrides["moe_capacity_factor"] = args.capacity_factor
                        if args.block_k:
                            overrides["attn_block_k"] = args.block_k
                        if args.no_vocab_shard:
                            overrides["logits_vocab_shard"] = False
                        if args.moe_local_dispatch:
                            overrides["moe_local_dispatch"] = True
                        rec = run_cell(arch, shape_name, multi, plan, overrides)
                    path.write_text(json.dumps(rec, indent=2, default=float))
                    if "skipped" in rec:
                        print(f"[skipped] {rec['skipped']}")
                    else:
                        t = rec["terms"]
                        print(
                            f"[ok] compile={rec['compile_s']}s "
                            f"compute={t['compute_s']:.4f}s memory={t['memory_s']:.4f}s "
                            f"collective={t['collective_s']:.4f}s dominant={t['dominant']}",
                            flush=True,
                        )
                except Exception as e:  # noqa: BLE001 — sweep must survive cell bugs
                    failures.append(label)
                    path.with_suffix(".error").write_text(traceback.format_exc())
                    print(f"[FAIL] {label}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILED cells: {failures}")
        raise SystemExit(1)
    print("\nall requested cells compiled OK")


if __name__ == "__main__":
    main()
