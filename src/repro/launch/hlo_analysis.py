"""While-loop-aware analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**, so a
scan-over-layers transformer under-reports FLOPs/bytes/collectives by the
trip count (layers x microbatches x attention blocks).  This module parses
``compiled.as_text()`` and:

1. builds the computation call graph (while bodies/conditions, fusion
   ``calls=``, reduction ``to_apply=``);
2. reads each while's trip count from ``backend_config={"known_trip_count"}``
   (fallback: the s32 constant in its condition computation);
3. propagates execution multipliers from ENTRY down the graph;
4. accumulates, with multipliers:
   * **dot/convolution FLOPs** (2 x prod(result) x contraction size),
   * **collective wire bytes** (ring-model factors per op, group size from
     ``replica_groups``),
   * an **HBM-traffic proxy** (``bytes_proxy``): matmul/conv operand+result
     bytes plus collective payloads.  Rationale: on TPU, elementwise chains
     fuse into their matmul producers/consumers, so HBM round-trips happen
     at contraction boundaries; summing every instruction's result (also
     recorded, as ``bytes_all_results``) would instead measure the *CPU*
     backend's unfused materialisation and overstate TPU traffic ~50x.

All quantities are per-device (the module is the SPMD-partitioned program).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Iterator

__all__ = ["analyze_hlo", "HloStats"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%(?P<name>[^\s(]+)\s*\(.*\)\s*->\s*.*\{")
_SHAPED_RE = re.compile(r"^(?P<dtype>\w+)\[(?P<shape>[\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[^\s=]+)\s*=\s*(?P<type>\([^=]*?\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>[\w\-]+)\((?P<rest>.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w\.\-]+)")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
    "while", "conditional", "call",
}


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_proxy: float = 0.0        # dot/conv operands+results + collectives
    bytes_all_results: float = 0.0  # every materialised result x2 (diagnostic)
    wire_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    n_whiles: int = 0
    unknown_trip_whiles: int = 0


def _shape_bytes(type_str: str) -> float:
    """Bytes of a (possibly tuple) result type."""
    total = 0.0
    for dt, shp in re.findall(r"(\w+)\[([\d,]*)\]", type_str):
        el = _DTYPE_BYTES.get(dt, 4)
        n = 1
        for d in shp.split(","):
            if d:
                n *= int(d)
        total += el * n
    return total


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    current: str | None = None
    for line in text.splitlines():
        m = _HEADER_RE.match(line.strip()) if not line.startswith(" ") else None
        if m and line.rstrip().endswith("{"):
            current = m.group("name")
            comps[current] = []
        elif line.startswith("}"):
            current = None
        elif current is not None:
            comps[current].append(line)
    return comps


def _entry_name(text: str) -> str:
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _HEADER_RE.match(line.strip())
            if m:
                return m.group("name")
    raise ValueError("no ENTRY computation found")


def _instructions(lines: list[str]) -> Iterator[re.Match]:
    for line in lines:
        m = _INSTR_RE.match(line)
        if m:
            yield m


def _build_multipliers(comps: dict[str, list[str]], entry: str) -> tuple[dict[str, float], int, int]:
    """Propagate execution counts from ENTRY through whiles/calls."""
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    n_whiles = unknown = 0
    # topological-ish fixed point: callees always appear before callers in
    # HLO text, so iterate a few passes to converge on nested structures.
    for _ in range(12):
        changed = False
        snapshot = dict(mult)
        mult = defaultdict(float)
        mult[entry] = 1.0
        for comp, lines in comps.items():
            base = snapshot.get(comp, 0.0)
            if base == 0.0:
                continue
            for line in lines:
                if " while(" in line:
                    trip_m = _TRIP_RE.search(line)
                    trip = int(trip_m.group(1)) if trip_m else 1
                    body = re.search(r"body=%?([\w\.\-]+)", line)
                    cond = re.search(r"condition=%?([\w\.\-]+)", line)
                    if body:
                        mult[body.group(1)] += base * trip
                    if cond:
                        mult[cond.group(1)] += base * (trip + 1)
                else:
                    for callee in _CALL_RE.findall(line):
                        mult[callee] += base
        if dict(mult) != dict(snapshot):
            changed = True
        if not changed:
            break
    for comp, lines in comps.items():
        for line in lines:
            if " while(" in line:
                n_whiles += 1
                if not _TRIP_RE.search(line):
                    unknown += 1
    return dict(mult), n_whiles, unknown


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return world


def _wire_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op.startswith("all-reduce"):
        return 2.0 * (n - 1) / n
    if op.startswith("all-gather"):
        return (n - 1) / n
    if op.startswith("reduce-scatter"):
        return float(n - 1)
    if op.startswith("all-to-all"):
        return (n - 1) / n
    return 1.0  # collective-permute


def analyze_hlo(text: str, world: int) -> HloStats:
    comps = _split_computations(text)
    entry = _entry_name(text)
    mult, n_whiles, unknown = _build_multipliers(comps, entry)
    stats = HloStats(n_whiles=n_whiles, unknown_trip_whiles=unknown)

    for comp, lines in comps.items():
        m_c = mult.get(comp, 0.0)
        if m_c == 0.0:
            continue
        # local symbol table: instruction name -> result type string
        symbols: dict[str, str] = {}
        for ins in _instructions(lines):
            symbols[ins.group("name")] = ins.group("type")
        # parameters carry shapes too
        for line in lines:
            pm = re.match(r"^\s*%([\w\.\-]+)\s*=\s*(\([^=]*?\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s*parameter", line)
            if pm:
                symbols[pm.group(1)] = pm.group(2)

        for ins in _instructions(lines):
            op = ins.group("op")
            type_str = ins.group("type")
            rest = ins.group("rest")
            rbytes = _shape_bytes(type_str)
            if op not in _SKIP_BYTES_OPS:
                stats.bytes_all_results += m_c * rbytes * 2.0
            if op == "dot":
                out_elems = 1
                sm = _SHAPED_RE.match(type_str)
                if sm and sm.group("shape"):
                    for d in sm.group("shape").split(","):
                        out_elems *= int(d)
                contract = 1
                operand_bytes = 0.0
                cm = _CONTRACT_RE.search(rest)
                ops = _OPERAND_RE.findall(rest.split(")")[0])
                for name in ops[:2]:
                    operand_bytes += _shape_bytes(symbols.get(name, ""))
                if cm and ops:
                    lhs_type = symbols.get(ops[0], "")
                    lm = _SHAPED_RE.match(lhs_type)
                    if lm and lm.group("shape"):
                        dims = [int(d) for d in lm.group("shape").split(",")]
                        for idx in cm.group(1).split(","):
                            if idx:
                                contract *= dims[int(idx)]
                stats.flops += m_c * 2.0 * out_elems * contract
                stats.bytes_proxy += m_c * (operand_bytes + rbytes)
            elif op == "convolution":
                # 2 * prod(out) * (kernel spatial x in_features / groups):
                # approximate contraction from rhs operand size / out_features
                ops = _OPERAND_RE.findall(rest.split(")")[0])
                rhs_type = symbols.get(ops[1], "") if len(ops) > 1 else ""
                rm = _SHAPED_RE.match(rhs_type)
                out_elems = _shape_bytes(type_str) / max(
                    _DTYPE_BYTES.get(_SHAPED_RE.match(type_str).group("dtype"), 4), 1
                )
                operand_bytes = sum(_shape_bytes(symbols.get(n, "")) for n in ops[:2])
                stats.bytes_proxy += m_c * (operand_bytes + rbytes)
                if rm and rm.group("shape"):
                    rdims = [int(d) for d in rm.group("shape").split(",")]
                    sm2 = _SHAPED_RE.match(type_str)
                    odims = [int(d) for d in sm2.group("shape").split(",") if d]
                    out_feat = odims[1] if len(odims) > 1 else 1
                    rhs_elems = 1
                    for d in rdims:
                        rhs_elems *= d
                    contract = rhs_elems / max(out_feat, 1)
                    stats.flops += m_c * 2.0 * out_elems * contract
            elif op.split("-start")[0] in (
                "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute",
            ):
                base = op.split("-start")[0]
                n = _group_size(rest, world)
                payload = rbytes
                if op.endswith("-start") and type_str.startswith("("):
                    payload = rbytes / 2.0  # (operand, result) tuple
                wire = payload * _wire_factor(base, n)
                stats.wire_bytes += m_c * wire
                stats.bytes_proxy += m_c * payload  # HBM side of the collective
                d = stats.collectives.setdefault(
                    base, {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0}
                )
                d["count"] += m_c
                d["bytes"] += m_c * payload
                d["wire_bytes"] += m_c * wire
    return stats
