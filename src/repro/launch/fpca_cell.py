"""The paper-representative dry-run cell: the FPCA frontend at production
scale, on the production mesh.

Workload: a video/sensor-fleet frontend — ``batch`` frames of
``sensor x sensor`` RGB through the 5x5x3, 8-channel, stride-5 FPCA
convolution in its TPU-native basis-expanded form (exactly the Pallas
kernel's math; Pallas itself does not lower on the CPU backend).  Frames
shard over the data axes; the window axis shards over ``model`` (the conv is
embarrassingly parallel over windows, so TP costs nothing — the interesting
roofline question is arithmetic intensity, not communication).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig
from repro.core.fpca_sim import WeightEncoding, encode_weights, extract_windows
from repro.core.mapping import FPCASpec, output_dims
from repro.kernels.fpca_conv.ops import fpca_conv_basis_jnp, freeze_model, pad_to_lanes, thaw_model
from repro.launch.mesh import data_axes

__all__ = ["FPCA_SHAPES", "build_fpca_cell", "FpcaCellInfo"]


@dataclasses.dataclass(frozen=True)
class FpcaShape:
    name: str
    sensor: int
    global_batch: int
    kind: str = "frontend"


# sensor sizes are multiples of stride x |model axis| (5 x 16 = 80) so the
# image height shards over 'model' with window extraction fully local
FPCA_SHAPES = {
    "video_1080": FpcaShape("video_1080", 1120, 256),   # HD-class
    "sensor_4k": FpcaShape("sensor_4k", 2240, 32),      # 4K-class
}

SPEC_TEMPLATE = dict(out_channels=8, kernel=5, stride=5, max_kernel=5)


@dataclasses.dataclass(frozen=True)
class FpcaCellInfo:
    """Just enough of the ModelConfig protocol for roofline accounting."""

    name: str
    spec: FPCASpec
    batch: int

    def active_param_count(self) -> int:
        s = self.spec
        return s.out_channels * s.kernel * s.kernel * s.in_channels

    @property
    def windows(self) -> int:
        h_o, w_o = output_dims(self.spec)
        return h_o * w_o

    def model_flops(self) -> float:
        """Useful work: the ideal convolution, both weight phases."""
        n = self.spec.n_active_pixels
        return 2.0 * self.batch * self.windows * n * self.spec.out_channels * 2


def build_fpca_cell(
    shape: FpcaShape, mesh, model, *,
    fuse_phases: bool = False, compute_dtype=None, row_shard: bool = False,
) -> tuple[Any, tuple, FpcaCellInfo]:
    """Returns (jitted step, SDS args, info). ``model`` is a fitted
    BucketCurvefitModel (concrete numpy tables).

    ``fuse_phases`` / ``compute_dtype`` are the §Perf levers for this cell."""
    spec = FPCASpec(image_h=shape.sensor, image_w=shape.sensor, **SPEC_TEMPLATE)
    info = FpcaCellInfo(name="fpca-frontend", spec=spec, batch=shape.global_batch)
    adc = ADCConfig()
    enc = WeightEncoding()
    frozen = freeze_model(model)
    dp = data_axes(mesh)

    # row_shard: fold row-groups into the batch dim at the INPUT layout —
    # (B, H, W, C) -> (B * m, H/m, W, C) with the leading dim sharded over
    # (data axes + 'model').  Window extraction is local (s == n: no halo),
    # so every chip owns 1/256th of the windows with zero in-graph
    # resharding.  (The with_sharding_constraint version of this idea was
    # refuted: the vmap'd extraction reshapes broke the constraint and the
    # forced reshard cost more than it saved — EXPERIMENTS.md §Perf.)
    m_size = dict(mesh.shape).get("model", 1) if row_shard else 1
    if (shape.sensor // SPEC_TEMPLATE["stride"]) % m_size:
        raise ValueError("sensor rows must divide the model axis for row_shard")
    group_h = shape.sensor // m_size
    group_spec = FPCASpec(image_h=group_h, image_w=shape.sensor, **SPEC_TEMPLATE)

    def step(images, kernel, bn_offset):
        m = thaw_model(frozen)
        w_pos, w_neg = encode_weights(kernel, group_spec, enc)
        patches = extract_windows(images, group_spec)   # batched natively
        Bg, h_o, w_o, N = patches.shape
        flat = patches.reshape(Bg * h_o * w_o, N)
        flat, mask = pad_to_lanes(flat, axis=1)
        w_pos_p, _ = pad_to_lanes(w_pos.T, axis=0)
        w_neg_p, _ = pad_to_lanes(w_neg.T, axis=0)
        counts = fpca_conv_basis_jnp(
            flat, w_pos_p, w_neg_p, m, adc, bn_offset, mask=mask,
            n_real=spec.n_active_pixels,
            fuse_phases=fuse_phases, compute_dtype=compute_dtype,
        )
        return counts.reshape(Bg, h_o, w_o, -1)[..., : spec.out_channels]

    P = jax.sharding.PartitionSpec
    lead_axes = dp + ("model",) if row_shard else dp
    img_sds = jax.ShapeDtypeStruct(
        (shape.global_batch * m_size, group_h, shape.sensor, 3),
        jnp.bfloat16,
        sharding=jax.sharding.NamedSharding(mesh, P(lead_axes, None, None, None)),
    )
    k = spec.kernel
    kern_sds = jax.ShapeDtypeStruct(
        (spec.out_channels, k, k, spec.in_channels), jnp.float32,
        sharding=jax.sharding.NamedSharding(mesh, P()),
    )
    bn_sds = jax.ShapeDtypeStruct(
        (spec.out_channels,), jnp.float32,
        sharding=jax.sharding.NamedSharding(mesh, P()),
    )
    return jax.jit(step), (img_sds, kern_sds, bn_sds), info
