"""Serving driver: continuous batched greedy decoding.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 8 --prompt-len 64 --tokens 64

Production posture: a single jitted decode step over a fixed-capacity batch;
finished sequences are replaced by queued requests between steps (continuous
batching at step granularity).  The same decode step is what the decode
dry-run cells lower at 256/512-chip scale.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduce_for_smoke
from repro.models.transformer import init_model
from repro.serving.serve_step import make_decode_step, make_prefill_step


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--requests", type=int, default=16, help="total request count")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    max_len = args.prompt_len + args.tokens + 8
    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len, remat="none"))
    decode = jax.jit(make_decode_step(cfg))

    rng = np.random.default_rng(args.seed)
    pending = [
        rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]
    done: list[np.ndarray] = []
    t_start = time.time()
    total_decoded = 0

    while pending:
        wave = pending[: args.batch]
        pending = pending[args.batch :]
        prompts = np.stack(
            wave + [wave[-1]] * (args.batch - len(wave))  # pad the last wave
        )
        frontend = None
        if cfg.family == "vlm":
            frontend = jnp.asarray(
                rng.normal(0, 1, (args.batch, cfg.frontend_tokens, cfg.frontend_dim)),
                jnp.float32,
            )
        elif cfg.family == "encdec":
            frontend = jnp.asarray(
                rng.normal(0, 1, (args.batch, args.prompt_len, cfg.frontend_dim)),
                jnp.float32,
            )
        tok, _, cache = prefill(params, jnp.asarray(prompts), frontend)
        tok = tok[:, None]
        pos0 = args.prompt_len + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
        outs = [tok]
        for step in range(args.tokens - 1):
            tok, _, cache = decode(params, tok, cache, jnp.int32(pos0 + step))
            outs.append(tok)
        seqs = np.asarray(jnp.concatenate(outs, axis=1))
        done.extend(seqs[: len(wave)])
        total_decoded += len(wave) * args.tokens
        print(f"[serve] wave done: {len(done)}/{args.requests} requests", flush=True)

    dt = time.time() - t_start
    print(
        f"[serve] {args.requests} requests, {total_decoded} tokens in {dt:.1f}s "
        f"({total_decoded/dt:.0f} tok/s decode throughput)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
