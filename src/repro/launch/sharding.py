"""Per-architecture sharding rules: DP / FSDP / TP / SP as PartitionSpecs.

Strategy (DESIGN.md §5):

* **FSDP** over the ``data`` axis: every matmul weight shards its *input*
  (reduction) dimension over ``data``; GSPMD all-gathers on use and
  reduce-scatters the gradients — ZeRO-3 semantics with no hand-written
  collectives.
* **TP** over the ``model`` axis: attention heads / FFN hidden / expert FFN
  hidden / Mamba inner channels.  GSPMD pads non-divisible head counts; the
  roofline report quantifies that waste per arch (hillclimb lever).
* **DP** additionally over ``pod`` (multi-pod): the batch is sharded over
  ``(pod, data)``; the only cross-pod collective is the gradient all-reduce.
* **SP** (sequence sharding) for the batch=1 ``long_500k`` decode cells: the
  KV-cache/sequence axis shards over ``data``, and attention reductions over
  the sharded axis become GSPMD-inserted collectives.

``ShardingPolicy`` lets hillclimb iterations flip individual levers
(fsdp on/off, tp on/off, expert-parallel opt-in) without touching model code.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import data_axes

__all__ = ["ShardingPolicy", "param_shardings", "batch_shardings", "cache_shardings"]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = True           # shard weight reduction dims over 'data'
    tp: bool = True             # shard heads/hidden over 'model'
    expert_parallel: bool = False  # shard the expert axis over 'model' (needs E % axis == 0)
    expert_tp: bool = True      # TP the expert ff dim (off: replicate-at-use,
    #                             trades small weight gathers for no act psums)
    seq_shard_batch1: bool = True  # SP for batch-1 decode caches

    def d(self) -> str | None:  # FSDP axis
        return "data" if self.fsdp else None

    def m(self) -> str | None:  # TP axis
        return "model" if self.tp else None


# Trailing-dims rules: suffix regex -> spec builder(policy) over trailing dims.
# Leading stacked-layer/group dims are padded with None automatically.
def _rules(p: ShardingPolicy) -> list[tuple[str, tuple]]:
    d, m = p.d(), p.m()
    ep = m if p.expert_parallel else None
    # expert ff dim: TP unless EP owns the model axis or expert_tp disabled
    ef = None if (p.expert_parallel or not p.expert_tp) else m
    return [
        # tables: vocab replicated (clean gathers), d FSDP'd; the logits
        # matmul re-shards vocab-over-model in-graph (see layers.unembed)
        (r"embed/table$", (None, d)),
        (r"unembed/table$", (None, d)),
        (r"shared_gate/w$", (d, None)),        # before the generic gate rule
        (r"(?:^|/)(wq|wk|wv)/w$", (d, m)),
        (r"(?:^|/)wo/w$", (m, d)),
        (r"(?:^|/)(gate|up|w1)/w$", (d, m)),   # swiglu/mlp/projector up
        (r"(?:^|/)(down|w2)/w$", (m, d)),
        # experts: ZeRO-3 storage (FSDP on d) + TP on ff.  Every layout
        # that replicates expert weights or constrains them at use pays the
        # f32 weight-cotangent reshard inside scan-bwd and is 3-11x worse —
        # both alternatives measured and refuted in EXPERIMENTS.md §Perf.
        (r"experts/(gate|up)$", (ep, d, ef)),
        (r"experts/down$", (ep, ef, d)),
        (r"router/w$", (d, None)),
        (r"in_proj/w$", (d, m)),
        (r"out_proj/w$", (m, d)),
        (r"conv_w$", (None, m)),
        (r"conv_b$", (m,)),
        (r"(a_log|dt_bias|d_skip|norm_scale)$", ()),
        (r"src_proj/w$", (d, m)),
        (r"scale$", ()),
    ]


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
    return "/".join(parts)


def _spec_for(path_str: str, ndim: int, rules) -> P:
    for pattern, trailing in rules:
        if re.search(pattern, path_str):
            if len(trailing) > ndim:
                trailing = trailing[len(trailing) - ndim :]
            pad = (None,) * (ndim - len(trailing))
            return P(*pad, *trailing)
    return P()  # replicate by default (norm scales etc.)


def param_shardings(
    params_shape: Any, mesh: Mesh, policy: ShardingPolicy = ShardingPolicy()
) -> Any:
    """Pytree of NamedShardings matching an eval_shape'd params tree."""
    rules = _rules(policy)

    def one(path, leaf):
        spec = _spec_for(_path_str(path), leaf.ndim, rules)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_shardings(mesh: Mesh, batch_shape: Any) -> Any:
    """Batch dims shard over (pod, data); everything else replicated."""
    dp = data_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(one, batch_shape)


def cache_shardings(
    cache_shape: Any,
    mesh: Mesh,
    batch: int,
    policy: ShardingPolicy = ShardingPolicy(),
) -> Any:
    """Decode-cache shardings.

    KV leaves are (..., B, S, KV, D); SSM states (..., B, H, P, N); conv
    states (..., B, K, conv).  Batch shards over (pod, data) when divisible;
    batch=1 long-context cells shard the KV sequence axis instead (SP).
    """
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_ok = batch % dp_size == 0 and batch >= dp_size
    m = policy.m()
    m_size = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def ax(axis, dim):
        """Use ``axis`` only if it divides the dimension evenly (explicit
        input shardings — unlike in-graph GSPMD — reject padding)."""
        if axis is None:
            return None
        size = m_size if axis == "model" else dp_size
        return axis if dim % size == 0 and dim >= size else None

    def one(path, leaf):
        name = _path_str(path).split("/")[-1]
        nd = leaf.ndim
        shp = leaf.shape
        if name in ("k", "v"):
            _, S, KV, D = shp[-4:]
            # GQA caches: shard heads when they divide the model axis.  When
            # they don't (kv < 16), shard the cache *sequence* over 'model':
            # attention over a seq-sharded cache costs only tiny softmax
            # max/sum + output psums.  (Sharding head_dim instead makes the
            # partitioner gather the whole cache every step — measured 100 GB
            # per decoded token on internvl2; EXPERIMENTS.md §Perf.)
            head_ax = ax(m, KV)
            seq_ax = ax(m, S) if head_ax is None else None
            if batch_ok:
                trailing = (dp, seq_ax, head_ax, None)
            elif policy.seq_shard_batch1:
                trailing = (None, ax("data", S), head_ax, None)  # SP cache
            else:
                trailing = (None, seq_ax, head_ax, None)
        elif name == "ssm":
            _, H, _, _ = shp[-4:]
            trailing = (dp if batch_ok else None, ax(m, H), None, None)
        elif name == "conv":
            _, _, C = shp[-3:]
            trailing = (dp if batch_ok else None, None, ax(m, C))
        else:
            trailing = tuple([None] * nd)
        pad = (None,) * (nd - len(trailing))
        return NamedSharding(mesh, P(*pad, *trailing))

    return jax.tree_util.tree_map_with_path(one, cache_shape)
