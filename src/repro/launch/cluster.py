"""Multi-host cluster launch helper.

This container has one host; on a real v5e deployment each host runs the
same training entrypoint under ``jax.distributed.initialize``.  This module
(1) performs the per-host initialisation when env vars are present, and
(2) generates the per-host launch commands for a pod-slice — the piece of
glue a scheduler (GKE/XPK/Ray) consumes.

Fault tolerance at cluster level (DESIGN.md §5):
* every host runs the same resumable loop (launch/train.py): on preemption
  the job restarts from the latest checkpoint with a possibly *different*
  host/device count — elastic resharding in training/checkpoint.py handles
  the re-layout;
* stragglers: the data pipeline's stall deadline surfaces slow hosts; the
  runbook action is to restart without that host (elastic), not to block;
* cross-pod traffic is only the gradient all-reduce over the ``pod`` axis
  (optionally int8-compressed, training/compression.py).
"""

from __future__ import annotations

import argparse
import os

__all__ = ["maybe_init_distributed", "launch_commands"]


def maybe_init_distributed() -> bool:
    """Initialise jax.distributed from standard env vars if present."""
    coord = os.environ.get("COORDINATOR_ADDRESS")
    if not coord:
        return False
    import jax

    jax.distributed.initialize(
        coordinator_address=coord,
        num_processes=int(os.environ["NUM_PROCESSES"]),
        process_id=int(os.environ["PROCESS_ID"]),
    )
    return True


def launch_commands(
    *,
    hosts: int,
    coordinator: str,
    arch: str,
    pods: int = 1,
    extra: str = "",
) -> list[str]:
    """Per-host command lines for a (pods x 16 x 16)-chip job."""
    cmds = []
    for pid in range(hosts):
        env = (
            f"COORDINATOR_ADDRESS={coordinator} NUM_PROCESSES={hosts} PROCESS_ID={pid} "
            f"LIBTPU_INIT_ARGS='--xla_tpu_enable_async_collective_fusion=true "
            f"--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true'"
        )
        cmds.append(
            f"{env} python -m repro.launch.train --arch {arch} {extra}".strip()
        )
    return cmds


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hosts", type=int, default=64, help="v5e-256: 64 hosts/pod")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--coordinator", default="10.0.0.2:8476")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--extra", default="--steps 10000 --ckpt-dir gs://bucket/ckpt")
    args = ap.parse_args()
    for cmd in launch_commands(
        hosts=args.hosts * args.pods,
        coordinator=args.coordinator,
        arch=args.arch,
        pods=args.pods,
        extra=args.extra,
    ):
        print(cmd)


if __name__ == "__main__":
    main()
