"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; everything else in the repo sees the real single CPU device.

Topology rationale (DESIGN.md §5): ``model`` is the fast-ICI minor axis
(tensor parallel), ``data`` the second intra-pod axis (FSDP + data parallel),
``pod`` the cross-pod axis that only ever carries gradient all-reduces — the
one pattern that scales to thousands of nodes.
"""

from __future__ import annotations

import jax
import numpy as np

from repro import compat

__all__ = ["make_production_mesh", "make_host_mesh", "data_axes", "data_extent"]


def _mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return compat.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {dict(zip(axes, shape))}, have {len(devices)} — "
            "run via launch/dryrun.py (it forces 512 host devices)"
        )
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over however many (virtual) devices tests run with."""
    return _mesh((data, model), ("data", "model"))


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes that carry the batch dimension (pure DP + FSDP axes)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def data_extent(mesh: jax.sharding.Mesh) -> int:
    """Total device count along the batch-carrying axes — the multiple a
    data-parallel batch must pad to (used by the FPCA serving handles)."""
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)], dtype=np.int64))
