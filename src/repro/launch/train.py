"""Training driver: resumable, checkpointed, fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 200 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt

Fault-tolerance behaviours (exercised by tests/test_fault_tolerance.py):
* checkpoint every ``--ckpt-every`` steps (atomic rename, retention 3);
* SIGTERM/SIGINT -> final checkpoint, clean exit 0 (preemption handling);
* on start, auto-resume from the latest checkpoint (params, optimizer
  moments, data cursor, RNG) — training continues bit-exactly;
* data pipeline prefetches on a worker thread with a stall deadline.
"""

from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCHS, reduce_for_smoke
from repro.data.pipeline import LMStreamConfig, PrefetchIterator, SyntheticLM
from repro.models.transformer import init_model
from repro.training.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.train_step import make_train_step


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced same-family config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if cfg.family in ("vlm", "encdec") and args.smoke:
        cfg = dataclasses.replace(cfg, frontend_tokens=min(cfg.frontend_tokens, 4))

    key = jax.random.PRNGKey(args.seed)
    params = init_model(key, cfg)
    opt_state = init_adamw(params)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, n_micro=args.n_micro, remat=args.remat)
    )

    start_step = 0
    ckpt_dir = Path(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        (params, opt_state), extra = restore_checkpoint(ckpt_dir, (params, opt_state))
        start_step = int(extra["step"])
        print(f"[train] resumed from step {start_step}", flush=True)

    stream_cfg = LMStreamConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq_len - (cfg.frontend_tokens if cfg.family == "vlm" else 0),
        global_batch=args.global_batch,
        seed=args.seed,
    )
    stream = SyntheticLM(stream_cfg)

    def make_batch(step: int):
        batch = {k: np.asarray(v) for k, v in stream.batch_at(step).items()}
        if cfg.family == "vlm":
            rng = np.random.default_rng((args.seed, step, 7))
            batch["frontend"] = rng.normal(
                0, 1, (args.global_batch, cfg.frontend_tokens, cfg.frontend_dim)
            ).astype(np.float32)
        elif cfg.family == "encdec":
            rng = np.random.default_rng((args.seed, step, 7))
            batch["frontend"] = rng.normal(
                0, 1, (args.global_batch, args.seq_len, cfg.frontend_dim)
            ).astype(np.float32)
        return batch

    prefetch = PrefetchIterator(make_batch, start_step=start_step, timeout_s=120.0)

    stop = {"flag": False}

    def _graceful(signum, frame):  # noqa: ARG001
        print(f"[train] signal {signum}: checkpointing and exiting", flush=True)
        stop["flag"] = True

    signal.signal(signal.SIGTERM, _graceful)
    signal.signal(signal.SIGINT, _graceful)

    def checkpoint(step: int) -> None:
        if ckpt_dir:
            save_checkpoint(
                ckpt_dir, step, (params, opt_state), extra={"arch": cfg.name, "seed": args.seed}
            )

    t_start = time.time()
    losses = []
    step = start_step
    try:
        while step < args.steps and not stop["flag"]:
            got_step, batch = next(prefetch)
            assert got_step == step, f"pipeline cursor mismatch {got_step} != {step}"
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            step += 1
            if step % args.log_every == 0:
                dt = (time.time() - t_start) / max(step - start_step, 1)
                print(
                    f"[train] step {step:5d} loss {losses[-1]:.4f} "
                    f"grad_norm {float(metrics['grad_norm']):.3f} "
                    f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f} ms/step",
                    flush=True,
                )
            if step % args.ckpt_every == 0:
                checkpoint(step)
    finally:
        prefetch.close()
    checkpoint(step)
    if len(losses) >= 20:
        first, last = np.mean(losses[:10]), np.mean(losses[-10:])
        print(f"[train] loss {first:.4f} -> {last:.4f} over {step - start_step} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
