"""Cell machinery: (architecture x input-shape x mesh) -> lowered step.

A *cell* is one entry of the dry-run matrix.  This module builds the step
function, the ShapeDtypeStruct inputs (with shardings attached — no device
allocation ever happens), lowers and compiles it, and extracts the roofline
raw material (cost analysis, memory analysis, collective bytes from the
optimized HLO).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import data_axes
from repro.launch.sharding import (
    ShardingPolicy,
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from repro.models.transformer import init_cache, init_model
from repro.serving.serve_step import make_decode_step, make_prefill_step
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.train_step import make_train_step, pick_microbatches

__all__ = ["CellPlan", "build_cell", "lower_cell"]


@dataclasses.dataclass(frozen=True)
class CellPlan:
    """Tunable levers of one cell (the hillclimb knobs)."""

    policy: ShardingPolicy = ShardingPolicy()
    remat: str = "full"
    n_micro: int = 0            # 0 -> auto via pick_microbatches
    donate: bool = True
    act_budget_bytes: float = 4e9


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(shapes: Any, shardings: Any) -> Any:
    return jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), shapes, shardings
    )


def _dp_size(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def _batch_geometry(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """Token/frontend layout for one shape; vlm reserves patch positions."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        s_text = S - cfg.frontend_tokens
        return {
            "tokens": (B, s_text),
            "labels": (B, s_text),
            "frontend": (B, cfg.frontend_tokens, cfg.frontend_dim),
        }
    if cfg.family == "encdec":
        return {"tokens": (B, S), "labels": (B, S), "frontend": (B, S, cfg.frontend_dim)}
    return {"tokens": (B, S), "labels": (B, S)}


def build_cell(
    cfg: ModelConfig, shape: ShapeSpec, mesh, plan: CellPlan = CellPlan()
) -> tuple[Any, tuple]:
    """Returns (jitted step fn, SDS args) for one cell; nothing is allocated."""
    params_shape = jax.eval_shape(
        lambda k: init_model(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    p_shard = param_shardings(params_shape, mesh, plan.policy)
    params_sds = _with_shardings(params_shape, p_shard)
    dp = _dp_size(mesh)
    B = shape.global_batch

    if shape.kind == "train":
        geo = _batch_geometry(cfg, shape)
        batch_shape = {
            k: _sds(v, jnp.int32 if k in ("tokens", "labels") else jnp.bfloat16)
            for k, v in geo.items()
        }
        b_shard = batch_shardings(mesh, batch_shape)
        batch_sds = _with_shardings(batch_shape, b_shard)
        per_dev = max(1, B // dp)
        n_micro = plan.n_micro or pick_microbatches(
            cfg, per_dev, shape.seq_len, plan.act_budget_bytes
        )
        opt_shape = jax.eval_shape(init_adamw, params_shape)
        o_shard = type(opt_shape)(
            step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            mu=p_shard,
            nu=p_shard,
        )
        opt_sds = _with_shardings(opt_shape, o_shard)
        step = make_train_step(cfg, AdamWConfig(), n_micro=n_micro, remat=plan.remat)
        jitted = jax.jit(step, donate_argnums=(0, 1) if plan.donate else ())
        return jitted, (params_sds, opt_sds, batch_sds)

    if shape.kind == "prefill":
        geo = _batch_geometry(cfg, shape)
        tokens_sds = _sds(
            geo["tokens"], jnp.int32,
            jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(data_axes(mesh), None)
            ),
        )
        args = [tokens_sds]
        if "frontend" in geo:
            fe_sds = _sds(
                geo["frontend"], jnp.bfloat16,
                jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec(data_axes(mesh), None, None)
                ),
            )
            args.append(fe_sds)
        step = make_prefill_step(cfg, max_len=shape.seq_len, remat=plan.remat)
        jitted = jax.jit(step)
        return jitted, (params_sds, *args)

    if shape.kind == "decode":
        cache_shape = jax.eval_shape(lambda: init_cache(cfg, B, shape.seq_len))
        c_shard = cache_shardings(cache_shape, mesh, B, plan.policy)
        # enc-dec: cross K/V filled at prefill; give it the same layout as self
        cache_sds = _with_shardings(cache_shape, c_shard)
        tok_spec = (
            jax.sharding.PartitionSpec(data_axes(mesh), None)
            if B % dp == 0 and B >= dp
            else jax.sharding.PartitionSpec()
        )
        token_sds = _sds((B, 1), jnp.int32, jax.sharding.NamedSharding(mesh, tok_spec))
        pos_sds = _sds((), jnp.int32, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()))
        step = make_decode_step(cfg)
        jitted = jax.jit(step, donate_argnums=(2,) if plan.donate else ())
        return jitted, (params_sds, token_sds, cache_sds, pos_sds)

    raise ValueError(shape.kind)


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, plan: CellPlan = CellPlan()):
    jitted, args = build_cell(cfg, shape, mesh, plan)
    return jitted.lower(*args)
