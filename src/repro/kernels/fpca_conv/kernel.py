"""Pallas TPU kernel for the FPCA analog convolution (bucket-select model).

TPU-native formulation (DESIGN.md §2): every windowed polynomial sum
factors over the monomial basis,

    sum_j f(I_j, W_j) = sum_{a,b} c_ab * <I_patch^a, W^b>,

so the whole non-linear analog conv = a bank of power-basis contractions
combined by sigmoid bucket gates.  The bank is rank-structured:

* (a=0, b)   -> per-channel constants  ``cs[b, c] = sum_j mask_j W[j,c]^b``
                (precomputed on host, no FLOPs in kernel);
* (a, b=0)   -> per-window vectors     ``rv[a, m] = <I^a, mask>``
                ((bm, N) @ (N, 1) — VPU-cheap);
* (a,b >= 1) -> true MXU matmuls, only (1,1), (1,2), (2,1) for the paper's
                degree-3 bucket surfaces;
* step-1 estimate -> one (bm, 15) @ (15, bc) matmul on window/channel means.

Both weight phases (CH_i positive cycle, CH_i_bar negative) are fused in one
kernel invocation together with the SS-ADC up/down counting epilogue, so the
patch tile is read from VMEM once per output tile.

Grid: (M / block_m, C / block_c); each program owns one output tile.
VMEM per program (defaults bm=256, bc=128, N=128):
  patches 128 KiB + 2 x w_pows 256 KiB + gates/acc scratch < 1 MiB  — far
  under the ~16 MiB budget, leaving headroom for double buffering.

Region skipping (§3.4.5) enters as a *row-compacted* patch matrix: the ops
layer gathers only the windows whose blocks survived the temporal delta gate
(padded to a static bucket), so the grid itself shrinks — fewer programs, not
masked-out results.  ``row_valid`` marks the real rows of the compacted
bucket; it multiplies the counts inside the fused epilogue so bucket-padding
rows scatter back as exact zeros (0.0/1.0 multiply — bit-exact on the kept
rows).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.adc import ADCConfig
from repro.core.curvefit import BucketCurvefitModel

__all__ = ["fpca_conv_pallas", "precompute_weight_planes"]

# Monomial pairs of the degree-3 bucket surfaces, grouped by rank structure.
_MM_PAIRS = ((1, 1), (1, 2), (2, 1))   # true matmuls
_VEC_AS = (1, 2, 3)                    # (a, 0): per-window vectors
_CONST_BS = (0, 1, 2, 3)               # (0, b): per-channel constants


def _bucket_tables(model: BucketCurvefitModel) -> dict[str, np.ndarray]:
    """Static per-model tables: combine coefficients keyed by (a, b) pair."""
    exps = [tuple(int(v) for v in e) for e in model.bucket_exps]
    coeffs = np.asarray(model.bucket_coeffs)          # (n_buckets, n_terms)
    v_c = np.asarray(model.v_centers)
    by_pair = {pair: coeffs[:, exps.index(pair)] / model.n_sweep for pair in exps}
    const = v_c * (1.0 - model.n_pixels / model.n_sweep)   # B_i affine offset
    return {"by_pair": by_pair, "const": const}


def precompute_weight_planes(
    w: jax.Array, mask: jax.Array, model: BucketCurvefitModel
) -> dict[str, jax.Array]:
    """Host-side precomputation for one weight phase (w: (N, C), mask: (N,)).

    Returns:
      w_pows : (2, N, C) — masked W^1, W^2 (the matmul operands)
      cs     : (4, C)    — per-channel constants sum_j mask W^b, b = 0..3
      aw     : (n_avg_terms, C) — f_avg coeffs folded with meanW powers
    """
    wm = w * mask[:, None]
    n_real = jnp.sum(mask)
    w_pows = jnp.stack([wm, wm * wm])                       # b = 1, 2
    cs = jnp.stack([mask @ jnp.ones_like(w), mask @ w, mask @ (w * w), mask @ (w * w * w)])
    mean_w = (mask @ w) / n_real                            # (C,)
    avg_exps = model.f_avg.exps
    aw = jnp.stack(
        [model.f_avg.coeffs[t] * mean_w ** int(avg_exps[t, 1]) for t in range(len(avg_exps))]
    )                                                       # (T_avg, C)
    return {"w_pows": w_pows, "cs": cs, "aw": aw}


def _fpca_kernel(
    # refs (order matches in_specs below)
    patches_ref, mask_ref, valid_ref,
    wp_pows_ref, wp_cs_ref, wp_aw_ref,
    wn_pows_ref, wn_cs_ref, wn_aw_ref,
    bn_ref,
    out_ref,
    *,
    tables: dict[str, Any],
    avg_a_exps: tuple[int, ...],
    n_real: float,
    n_buckets: int,
    sharpness: float,
    v_range: float,
    lsb: float,
    levels: int,
):
    x = patches_ref[...]                                    # (bm, N)
    maskv = mask_ref[...]                                   # (N, 1)
    x2 = x * x
    x3 = x2 * x
    xpows = {1: x, 2: x2, 3: x3}
    # per-window vectors <I^a, mask> and window mean
    rv = {a: jnp.dot(xpows[a], maskv) for a in _VEC_AS}     # (bm, 1) each
    mean_i = rv[1] / n_real                                 # (bm, 1)
    mi_pows = [mean_i ** a for a in avg_a_exps]             # list of (bm, 1)
    a_i = jnp.concatenate(mi_pows, axis=1)                  # (bm, T_avg)

    edges = np.arange(n_buckets, dtype=np.float32) / n_buckets
    coeff_by_pair = tables["by_pair"]
    const_b = tables["const"]

    def one_phase(pows_ref, cs_ref, aw_ref):
        # true matmuls (MXU)
        mm = {
            (a, b): jnp.dot(xpows[a], pows_ref[b - 1], preferred_element_type=jnp.float32)
            for (a, b) in _MM_PAIRS
        }                                                   # (bm, bc)
        cs = cs_ref[...]                                    # (4, bc)
        v_est = jnp.dot(a_i, aw_ref[...], preferred_element_type=jnp.float32)
        xg = v_est / v_range                                # (bm, bc)
        v_pred = jnp.zeros_like(xg)
        for i in range(n_buckets):
            gate = (
                jax.nn.sigmoid(sharpness * (xg - edges[i]))
                + jax.nn.sigmoid(sharpness * (edges[i] + 1.0 / n_buckets - xg))
                - 1.0
            )
            acc = jnp.full_like(xg, const_b[i])
            for (a, b), c in coeff_by_pair.items():
                ci = float(c[i])
                if a == 0:
                    acc += ci * cs[b][None, :]
                elif b == 0:
                    acc += ci * rv[a]
                else:
                    acc += ci * mm[(a, b)]
            v_pred += gate * acc
        return v_pred

    v_pos = one_phase(wp_pows_ref, wp_cs_ref, wp_aw_ref)
    v_neg = one_phase(wn_pows_ref, wn_cs_ref, wn_aw_ref)
    # SS-ADC epilogue: up/down count + BN counter init + ReLU/saturation clamp;
    # row validity (region-skip bucket padding) zeroes dead rows in-place —
    # a 0.0/1.0 multiply, exact on valid rows.
    up = jnp.clip(jnp.round(v_pos / lsb), 0, levels - 1)
    down = jnp.clip(jnp.round(v_neg / lsb), 0, levels - 1)
    out_ref[...] = valid_ref[...] * jnp.clip(bn_ref[...] + up - down, 0, levels - 1)


def fpca_conv_pallas(
    patches: jax.Array,
    w_pos: jax.Array,
    w_neg: jax.Array,
    model: BucketCurvefitModel,
    adc: ADCConfig,
    bn_offset: jax.Array,
    mask: jax.Array | None = None,
    *,
    n_real: int | None = None,
    row_valid: jax.Array | None = None,
    block_m: int = 256,
    block_c: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """FPCA analog conv counts, shape (M, C). See module docstring.

    ``patches (M, N)``, ``w_pos/w_neg (N, C)``, ``bn_offset (C,)``; N may be
    zero-padded — pass ``mask`` marking real pixel slots and ``n_real`` (the
    static count of real slots; required when tracing with a traced mask).
    ``row_valid (M,)`` marks real rows of a region-skip compacted bucket;
    rows with 0 come out as exact zeros (default: all rows valid).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    M, N = patches.shape
    C = w_pos.shape[1]
    if mask is None:
        mask = jnp.ones((N,), jnp.float32)
        n_real = n_real or N
    if n_real is None:
        n_real = int(np.sum(np.asarray(mask)))

    # ---- host-side padding to tile multiples --------------------------------
    Mp = -(-M // block_m) * block_m
    Cp = -(-C // block_c) * block_c
    patches_p = jnp.pad(patches.astype(jnp.float32), ((0, Mp - M), (0, 0)))
    w_pos_p = jnp.pad(w_pos.astype(jnp.float32), ((0, 0), (0, Cp - C)))
    w_neg_p = jnp.pad(w_neg.astype(jnp.float32), ((0, 0), (0, Cp - C)))
    bn_p = jnp.pad(bn_offset.astype(jnp.float32), (0, Cp - C))[None, :]
    if row_valid is None:
        row_valid = jnp.ones((M,), jnp.float32)
    valid_p = jnp.pad(row_valid.astype(jnp.float32), (0, Mp - M))[:, None]

    pp = precompute_weight_planes(w_pos_p, mask, model)
    pn = precompute_weight_planes(w_neg_p, mask, model)
    tables = _bucket_tables(model)
    avg_a_exps = tuple(int(a) for a, _ in model.f_avg.exps)
    t_avg = len(avg_a_exps)

    kernel = functools.partial(
        _fpca_kernel,
        tables=tables,
        avg_a_exps=avg_a_exps,
        n_real=float(n_real),
        n_buckets=model.n_buckets,
        sharpness=model.sharpness,
        v_range=model.v_range,
        lsb=adc.lsb,
        levels=adc.levels,
    )
    grid = (Mp // block_m, Cp // block_c)
    counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, N), lambda m, c: (m, 0)),       # patches
            pl.BlockSpec((N, 1), lambda m, c: (0, 0)),             # mask
            pl.BlockSpec((block_m, 1), lambda m, c: (m, 0)),       # row validity
            pl.BlockSpec((2, N, block_c), lambda m, c: (0, 0, c)),  # pos W^b
            pl.BlockSpec((4, block_c), lambda m, c: (0, c)),       # pos consts
            pl.BlockSpec((t_avg, block_c), lambda m, c: (0, c)),   # pos f_avg
            pl.BlockSpec((2, N, block_c), lambda m, c: (0, 0, c)),  # neg W^b
            pl.BlockSpec((4, block_c), lambda m, c: (0, c)),       # neg consts
            pl.BlockSpec((t_avg, block_c), lambda m, c: (0, c)),   # neg f_avg
            pl.BlockSpec((1, block_c), lambda m, c: (0, c)),       # bn offset
        ],
        out_specs=pl.BlockSpec((block_m, block_c), lambda m, c: (m, c)),
        out_shape=jax.ShapeDtypeStruct((Mp, Cp), jnp.float32),
        interpret=interpret,
    )(
        patches_p,
        mask[:, None].astype(jnp.float32),
        valid_p,
        pp["w_pows"], pp["cs"], pp["aw"],
        pn["w_pows"], pn["cs"], pn["aw"],
        bn_p,
    )
    return counts[:M, :C]
