from repro.kernels.fpca_conv.kernel import fpca_conv_pallas, precompute_weight_planes
from repro.kernels.fpca_conv.ops import (
    StickyBucket,
    fpca_conv,
    freeze_model,
    pad_to_lanes,
    thaw_model,
    window_bucket,
)
from repro.kernels.fpca_conv.ref import fpca_conv_ref

__all__ = [
    "StickyBucket",
    "fpca_conv",
    "fpca_conv_pallas",
    "fpca_conv_ref",
    "freeze_model",
    "pad_to_lanes",
    "precompute_weight_planes",
    "thaw_model",
    "window_bucket",
]
