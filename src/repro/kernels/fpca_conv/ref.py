"""Pure-jnp oracle for the fpca_conv kernel.

Deliberately built on the *independently tested* core modules
(:func:`repro.core.curvefit.predict_sigmoid`, :func:`repro.core.adc.updown_readout`)
rather than re-deriving the basis-expanded matmul form — so a bug in the
kernel's algebra cannot hide in its own oracle.

Layout contract (shared with the kernel):
  patches  (M, N)  — im2col windows (photocurrents), N = c_i * n * n real
                     pixels, optionally zero-padded to a lane multiple;
  w_pos/w_neg (N, C) — per-output-channel NVM conductance planes;
  mask     (N,)    — 1.0 for real pixel slots, 0.0 for padding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig, updown_readout
from repro.core.curvefit import BucketCurvefitModel, predict_sigmoid

__all__ = ["fpca_conv_ref"]


def _read(model: BucketCurvefitModel, patches: jax.Array, w: jax.Array, mask: jax.Array) -> jax.Array:
    """Bitline voltages, shape (M, C)."""
    # (M, 1, N) x (C, N) -> (M, C, N); padded slots forced to (I=0, W=0) so the
    # polynomial basis sees exactly the real-pixel statistics.
    I = patches[:, None, :] * mask
    W = (w.T * mask)[None, :, :]
    M, C, N = I.shape[0], W.shape[1], I.shape[-1]
    Ib = jnp.broadcast_to(I, (M, C, N))
    Wb = jnp.broadcast_to(W, (M, C, N))
    # predict_sigmoid averages I over the last axis for the step-1 estimate;
    # padding would bias the mean, so evaluate on the un-padded slice instead.
    n_real = int(mask.sum())
    return predict_sigmoid(model, Ib[..., :n_real], Wb[..., :n_real])


def fpca_conv_ref(
    patches: jax.Array,
    w_pos: jax.Array,
    w_neg: jax.Array,
    model: BucketCurvefitModel,
    adc: ADCConfig,
    bn_offset: jax.Array,
    mask: jax.Array | None = None,
) -> jax.Array:
    """Reference FPCA analog convolution: counts, shape (M, C)."""
    if mask is None:
        mask = jnp.ones((patches.shape[1],), jnp.float32)
    v_pos = _read(model, patches, w_pos, mask)
    v_neg = _read(model, patches, w_neg, mask)
    return updown_readout(v_pos, v_neg, adc, bn_offset, hard=True)
