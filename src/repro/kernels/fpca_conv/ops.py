"""Jitted public wrapper for the fpca_conv kernel: batched images in,
SS-ADC activation maps out.

Backend dispatch (``impl``): ``"pallas"`` is the TPU kernel — Pallas-compiled
on TPU, ``interpret=True`` elsewhere (the kernel body runs in Python on CPU
for validation); ``"basis"`` lowers the identical basis-expanded matmul-bank
math through XLA (:func:`fpca_conv_basis_jnp`) — the fast serving path on
hosts where Pallas does not compile.  The pure-jnp oracle lives in
:mod:`repro.kernels.fpca_conv.ref`.

Window extraction is batched natively: ``(B, H, W, c_i)`` images become one
flattened ``(B*h_o*w_o, N)`` patch matrix feeding a single fused kernel call
(no per-image Python loop).

Region skipping (§3.4.5) is *compute-real*: a per-window validity mask
(``window_mask``) gathers/compacts the flattened window list down to a static
bucket of ``m_bucket`` rows (``jnp.nonzero(..., size=m_bucket)``) before the
kernel runs, so skipped windows never reach the MXU.  Results scatter back to
the dense ``(B, h_o, w_o, c_o)`` grid with exact zeros in skipped slots; kept
windows are bit-identical to the dense evaluation because every row of the
basis-bank math is row-independent.  ``m_bucket`` is static (callers round
the kept-window count up to a power-of-two bucket via
:func:`window_bucket`), so recompiles stay bounded at ~log2(M) variants per
signature; when the bucket would not shrink the matrix (``m_bucket >= M``)
the impl falls back to dense compute with a post-hoc zero mask — identical
outputs, no gather overhead.

The fitted :class:`BucketCurvefitModel` enters the jitted function as a
*static* argument (hashable tuple encoding): its coefficient tables are baked
into the kernel as compile-time constants — exactly how a deployment would
ship a calibrated sensor model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adc import ADCConfig
from repro.core.curvefit import BucketCurvefitModel
from repro.core.fpca_sim import WeightEncoding, encode_weights, extract_windows
from repro.core.mapping import FPCASpec, output_dims
from repro.kernels.fpca_conv.kernel import fpca_conv_pallas

__all__ = [
    "fpca_conv",
    "fpca_conv_basis_jnp",
    "make_fpca_conv_executable",
    "pad_to_lanes",
    "freeze_model",
    "thaw_model",
    "window_bucket",
    "segment_bucket",
    "StickyBucket",
]

_LANES = 128

# int8 transfer: the bucket-sigmoid gate bank collapses into a LUT over the
# 8-bit requantised gate input (256 levels — the SS-ADC's own resolution).
_TRANSFER_LEVELS = 256


def _stable_sigmoid(z: np.ndarray) -> np.ndarray:
    out = np.empty_like(z)
    pos = z >= 0
    out[pos] = 1.0 / (1.0 + np.exp(-z[pos]))
    ez = np.exp(z[~pos])
    out[~pos] = ez / (1.0 + ez)
    return out


def _transfer_lut(model: BucketCurvefitModel, tables: dict) -> tuple:
    """Bake the bucket-sigmoid transfer into a 256-entry coefficient LUT.

    The f32 path evaluates, per element, ``v = sum_i gate_i(xg) * (const_i
    + sum_p c_i[p] * term_p)`` — ``n_buckets`` pairs of sigmoids over the
    full activation plane.  Swapping the sums gives ``v = ceff_const(xg) +
    sum_p ceff_p(xg) * term_p`` where every effective coefficient depends
    on ``xg`` alone, so requantising ``xg`` to 8 bits (the ADC's own level
    count) turns the whole gate bank into ONE gather from a ``(256,
    1 + n_pairs)`` table — the int8-DSP deployment form of a calibrated
    transfer curve.  Entries are evaluated at level centers in f64 and
    stored f32; parity against the sigmoid bank is bounded (<= 1 ADC LSB on
    a vanishing fraction of counts), pinned by the quant parity harness.
    """
    T = _TRANSFER_LEVELS
    grid = (np.arange(T, dtype=np.float64) + 0.5) / T
    edges = np.arange(model.n_buckets, dtype=np.float64) / model.n_buckets
    gates = np.stack(
        [
            _stable_sigmoid(model.sharpness * (grid - edges[i]))
            + _stable_sigmoid(
                model.sharpness * (edges[i] + 1.0 / model.n_buckets - grid)
            )
            - 1.0
            for i in range(model.n_buckets)
        ],
        axis=1,
    )                                               # (T, n_buckets)
    pairs = list(tables["by_pair"])
    cols = [gates @ np.asarray(tables["const"], np.float64)]
    cols += [
        gates @ np.asarray(tables["by_pair"][p], np.float64) for p in pairs
    ]
    return np.stack(cols, axis=1).astype(np.float32), pairs


def window_bucket(n_keep: int, m_total: int) -> int:
    """Static row-bucket size for ``n_keep`` kept windows out of ``m_total``.

    Power-of-two rounding keeps the set of compiled bucket variants bounded
    (~log2 of the window count); capped at ``m_total`` — at or above the cap
    the masked impl serves the dense fallback (same outputs, no gather).
    """
    return min(1 << (max(n_keep, 1) - 1).bit_length(), m_total)


def segment_bucket(
    kept_counts,
    m_total: int,
    keyframes=None,
) -> int:
    """Compacted-row bucket for the NEXT segment, from the per-tick kept
    counts of the last one (the between-segment half of the region-skip
    servo: inside a compiled segment the bucket is static, so the host picks
    it here at the boundary).

    Keyframe ticks are held out — they keep everything by construction and
    route through the segment's masked-dense branch anyway, so sizing the
    compact branch off them would permanently pin the bucket at ``m_total``.
    All-skipped ticks are ignored too (they launch nothing); a segment with
    no informative tick at all yields the minimal bucket of 1, which the
    overflow branch of the next segment absorbs if the scene wakes up.
    """
    kept = np.asarray(kept_counts, np.int64).reshape(-1)
    if keyframes is not None:
        kf = np.asarray(keyframes, bool).reshape(-1)
        kept = kept[~kf]
    kept = kept[kept > 0]
    if kept.size == 0:
        return 1
    return window_bucket(int(kept.max()), int(m_total))


class StickyBucket:
    """Cross-call hysteresis on :func:`window_bucket` (streaming §3.4.5).

    A busy scene makes per-tick kept-window counts oscillate across a
    power-of-two boundary, and a stateless :func:`window_bucket` then flaps
    the compiled bucket size between neighbours — every flap is an
    executable-cache switch (at worst a recompile, at best a working-set
    swap).  This helper holds the bucket *up*:

    * growth is immediate — the gather contract requires the bucket to hold
      every kept window, so a busier tick must switch up right away;
    * shrinkage waits for ``patience`` **consecutive** under-full ticks
      (raw bucket below the held one); only then does the bucket drop to the
      current tick's raw requirement.

    ``patience=1`` reproduces the stateless behaviour exactly (one
    under-full tick suffices).  ``switches`` counts bucket transitions
    actually served, ``shrinks_deferred`` the under-full ticks that kept the
    larger bucket — the flap events hysteresis absorbed.

    All-skipped ticks launch nothing, so they transition no executable —
    but they are maximally under-full, so callers report them via
    :meth:`observe_idle` to advance the shrink streak; after a quiet period
    of at least ``patience`` ticks, the first active tick shrinks
    immediately instead of serving a stale oversized bucket.
    """

    def __init__(self, patience: int = 4):
        if patience < 1:
            raise ValueError("patience must be >= 1")
        self.patience = patience
        self.bucket_size: int | None = None    # bucket currently held
        self.switches = 0
        self.shrinks_deferred = 0
        self._under = 0                        # consecutive under-full ticks

    def observe_idle(self) -> None:
        """Count an all-skipped tick (nothing served, no transition) toward
        the consecutive-under-full streak."""
        if self.bucket_size is not None:
            self._under += 1

    def bucket(self, n_keep: int, m_total: int) -> int:
        """Bucket to serve this tick's ``n_keep`` kept windows with."""
        raw = window_bucket(n_keep, m_total)
        held = self.bucket_size
        if held is None or raw > held:
            new = raw
            self._under = 0
        elif raw < held:
            self._under += 1
            if self._under >= self.patience:
                new = raw
                self._under = 0
            else:
                new = held
                self.shrinks_deferred += 1
        else:
            new = held
            self._under = 0
        if held is not None and new != held:
            self.switches += 1
        self.bucket_size = new
        return new


def _tup(x) -> tuple:
    return tuple(map(tuple, np.asarray(x).tolist())) if np.asarray(x).ndim > 1 else tuple(
        np.asarray(x).tolist()
    )


def freeze_model(model: BucketCurvefitModel) -> tuple:
    """Hashable encoding of a fitted model (for use as a jit static arg)."""
    d = model.to_dict()
    return (
        _tup(d["f_avg_coeffs"]), _tup(d["f_avg_exps"]),
        _tup(d["bucket_coeffs"]), _tup(d["bucket_exps"]),
        _tup(d["centers"]), _tup(d["v_centers"]),
        d["n_pixels"], d["n_sweep"], d["v_range"], d["sharpness"],
    )


def thaw_model(frozen: tuple) -> BucketCurvefitModel:
    """Inverse of :func:`freeze_model`.

    Keeps every table as *numpy* (not jnp): under jit tracing, jnp constants
    become tracers immediately (jax >= 0.8), which would break the host-side
    table construction in the kernel builder.  Numpy arrays stay concrete and
    are lifted to device constants only where they enter jnp ops.
    """
    from repro.core.curvefit import PolySurface

    (fa_c, fa_e, b_c, b_e, cen, v_c, n_px, n_sw, v_r, sharp) = frozen
    return BucketCurvefitModel(
        f_avg=PolySurface(
            coeffs=np.asarray(fa_c, np.float32), exps=np.asarray(fa_e, np.int32)
        ),
        bucket_coeffs=np.asarray(b_c, np.float32),
        bucket_exps=np.asarray(b_e, np.int32),
        centers=np.asarray(cen, np.float32),
        v_centers=np.asarray(v_c, np.float32),
        n_pixels=int(n_px),
        n_sweep=int(n_sw),
        v_range=float(v_r),
        sharpness=float(sharp),
    )


def pad_to_lanes(x: jax.Array, axis: int, lanes: int = _LANES) -> tuple[jax.Array, jax.Array]:
    """Zero-pad ``axis`` to a lane multiple; returns (padded, mask)."""
    n = x.shape[axis]
    target = -(-n // lanes) * lanes
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    mask = jnp.concatenate([jnp.ones((n,), jnp.float32), jnp.zeros((target - n,), jnp.float32)])
    return jnp.pad(x, pad), mask


def fpca_conv_basis_jnp(
    patches: jax.Array,
    w_pos: jax.Array,
    w_neg: jax.Array,
    model: BucketCurvefitModel,
    adc: ADCConfig,
    bn_offset: jax.Array,
    mask: jax.Array | None = None,
    n_real: int | None = None,
    *,
    row_valid: jax.Array | None = None,
    fuse_phases: bool = False,
    compute_dtype=None,
    transfer: str = "f32",
) -> jax.Array:
    """The Pallas kernel's exact math as a flat jnp program (no tiling).

    This is the TPU-native basis-expanded matmul-bank formulation
    (DESIGN.md §2) — used as the dry-run lowering path for the FPCA
    production cell (Pallas does not lower on the CPU backend) and by the
    kernel CPU benchmark.  The model must be *concrete* (numpy tables).

    ``row_valid (M,)``, if given, marks the real rows of a region-skip
    compacted patch bucket; invalid rows come out as exact zeros (same
    epilogue contract as the Pallas kernel).

    ``transfer="int8"`` serves the quantised bucket transfer: the gate
    input ``xg`` requantises to 8 bits and the whole sigmoid bank becomes
    one gather from the baked :func:`_transfer_lut` coefficient table —
    the dominant speed lane of ``precision="int8"`` model programs
    (parity-bounded, not bit-exact; selected only through backends with
    ``quant_transfer``).
    """
    from repro.kernels.fpca_conv.kernel import _bucket_tables, precompute_weight_planes

    if transfer not in ("f32", "int8"):
        raise ValueError(f"unknown transfer {transfer!r}")
    M, N = patches.shape
    if mask is None:
        mask = jnp.ones((N,), jnp.float32)
        n_real = n_real or N
    cdt = compute_dtype or jnp.float32
    tables = _bucket_tables(model)
    lut = lut_pairs = None
    if transfer == "int8":
        lut, lut_pairs = _transfer_lut(model, tables)
    x = patches.astype(cdt)
    x2, x3 = x * x, x * x * x
    xp = {1: x, 2: x2, 3: x3}
    maskv = mask[:, None].astype(cdt)

    def _dot(a, b):
        return jax.lax.dot(a, b.astype(a.dtype), preferred_element_type=jnp.float32)

    rv = {a: _dot(xp[a], maskv) for a in (1, 2, 3)}
    mean_i = rv[1] / n_real
    a_i = jnp.concatenate([mean_i ** int(a) for a, _ in model.f_avg.exps], axis=1)
    edges = np.arange(model.n_buckets, dtype=np.float32) / model.n_buckets

    def one_phase(w):
        planes = precompute_weight_planes(w, mask, model)
        mm = {(a, b): _dot(xp[a], planes["w_pows"][b - 1]) for (a, b) in ((1, 1), (1, 2), (2, 1))}
        v_est = _dot(a_i, planes["aw"])
        xg = v_est / model.v_range
        if transfer == "int8":
            # quantised transfer: one LUT gather replaces the sigmoid bank
            xg_q = jnp.clip(
                jnp.floor(xg * _TRANSFER_LEVELS).astype(jnp.int32),
                0, _TRANSFER_LEVELS - 1,
            )
            g = jnp.take(jnp.asarray(lut), xg_q, axis=0)    # (M, C, 1 + P)
            v_pred = g[..., 0]
            for k, (a, b) in enumerate(lut_pairs):
                if a == 0:
                    term = planes["cs"][b][None, :]
                elif b == 0:
                    term = rv[a]
                else:
                    term = mm[(a, b)]
                v_pred = v_pred + g[..., k + 1] * term
            return v_pred
        v_pred = jnp.zeros_like(xg)
        for i in range(model.n_buckets):
            gate = (
                jax.nn.sigmoid(model.sharpness * (xg - edges[i]))
                + jax.nn.sigmoid(model.sharpness * (edges[i] + 1.0 / model.n_buckets - xg))
                - 1.0
            )
            acc = jnp.full_like(xg, tables["const"][i])
            for (a, b), c in tables["by_pair"].items():
                ci = float(c[i])
                if a == 0:
                    acc += ci * planes["cs"][b][None, :]
                elif b == 0:
                    acc += ci * rv[a]
                else:
                    acc += ci * mm[(a, b)]
            v_pred += gate * acc
        return v_pred

    if fuse_phases:
        # both weight phases in one matmul bank: halves patch-operand reads
        # (the Pallas kernel gets this for free from VMEM tiling; this is the
        # XLA-lowering equivalent — §Perf target 3)
        C = w_pos.shape[1]
        v_both = one_phase(jnp.concatenate([w_pos, w_neg], axis=1))
        v_pos, v_neg = v_both[:, :C], v_both[:, C:]
    else:
        v_pos = one_phase(w_pos)
        v_neg = one_phase(w_neg)
    up = jnp.clip(jnp.round(v_pos / adc.lsb), 0, adc.levels - 1)
    down = jnp.clip(jnp.round(v_neg / adc.lsb), 0, adc.levels - 1)
    counts = jnp.clip(bn_offset[None, :] + up - down, 0, adc.levels - 1)
    if row_valid is not None:
        counts = counts * row_valid[:, None].astype(counts.dtype)
    return counts


def _fpca_conv_impl(
    images: jax.Array,
    kernel: jax.Array,
    bn_offset: jax.Array,
    window_mask: jax.Array | None = None,
    *,
    frozen: tuple,
    spec: FPCASpec,
    adc: ADCConfig,
    enc: WeightEncoding,
    block_m: int,
    block_c: int,
    interpret: bool | None,
    impl: str,
    m_bucket: int | None = None,
    transfer: str = "f32",
) -> jax.Array:
    if transfer != "f32" and impl != "basis":
        raise ValueError(
            f"transfer={transfer!r} is only lowered by the basis impl "
            f"(got impl={impl!r})"
        )
    model = thaw_model(frozen)
    w_pos, w_neg = encode_weights(kernel, spec, enc)            # (c_o, N)
    patches = extract_windows(images, spec)                     # (B, h_o, w_o, N)
    B, h_o, w_o, N = patches.shape
    M = B * h_o * w_o
    flat = patches.reshape(M, N)
    flat, mask = pad_to_lanes(flat, axis=1)
    w_pos_p, _ = pad_to_lanes(w_pos.T, axis=0)                  # (Np, c_o)
    w_neg_p, _ = pad_to_lanes(w_neg.T, axis=0)

    idx = row_valid = keep = None
    if window_mask is not None:
        if m_bucket is None:
            raise ValueError("window_mask requires a static m_bucket "
                             "(see window_bucket())")
        keep = jnp.reshape(window_mask, (-1,)).astype(bool)
        if m_bucket < M:
            # compact: only kept windows reach the kernel (row-independent
            # math, so kept rows stay bit-identical to a dense evaluation)
            (idx,) = jnp.nonzero(keep, size=m_bucket, fill_value=0)
            n_keep = jnp.sum(keep)
            row_valid = (jnp.arange(m_bucket) < n_keep).astype(jnp.float32)
            flat = flat[idx]

    if impl == "basis":
        counts = fpca_conv_basis_jnp(
            flat,
            w_pos_p,
            w_neg_p,
            model,
            adc,
            bn_offset,
            mask=mask,
            n_real=spec.n_active_pixels,
            row_valid=row_valid,
            transfer=transfer,
        )
    else:
        counts = fpca_conv_pallas(
            flat,
            w_pos_p,
            w_neg_p,
            model,
            adc,
            bn_offset,
            mask=mask,
            n_real=spec.n_active_pixels,
            row_valid=row_valid,
            block_m=block_m,
            block_c=block_c,
            interpret=interpret,
        )
    if keep is not None:
        if idx is not None:
            # scatter back to the dense window grid; bucket-padding rows are
            # exact zeros (kernel epilogue), so the fill-index add is a no-op
            counts = jnp.zeros((M, counts.shape[-1]), counts.dtype).at[idx].add(counts)
        else:
            # dense fallback (bucket would not shrink the matrix)
            counts = counts * keep[:, None].astype(counts.dtype)
    return counts.reshape(B, h_o, w_o, -1)


_fpca_conv_jit = functools.partial(
    jax.jit,
    static_argnames=(
        "frozen", "spec", "adc", "enc", "block_m", "block_c", "interpret", "impl",
        "m_bucket", "transfer",
    ),
)(_fpca_conv_impl)


def make_fpca_conv_executable(
    model: BucketCurvefitModel,
    *,
    spec: FPCASpec,
    adc: ADCConfig | None = None,
    enc: WeightEncoding | None = None,
    block_m: int = 256,
    block_c: int = 128,
    interpret: bool | None = None,
    impl: str = "pallas",
    m_bucket: int | None = None,
    transfer: str = "f32",
):
    """A fresh jitted ``(images, kernel, bn_offset) -> counts`` executable.

    Unlike :func:`fpca_conv` (which shares the module-level jit cache), each
    call returns an independently-jitted closure whose compiled programs die
    with it — this is what lets a serving cache genuinely *bound* live
    executables by dropping references (see
    :class:`repro.serving.fpca_pipeline.FPCAPipeline`).

    With ``m_bucket`` set, the executable instead takes
    ``(images, kernel, bn_offset, window_mask)`` and serves the region-skip
    compacted path: kept windows gathered into a static ``m_bucket`` row
    bucket, skipped windows never computed (see module docstring).
    CONTRACT: every mask fed to such an executable must keep at most
    ``m_bucket`` windows — the gather is a fixed-size ``jnp.nonzero`` and a
    busier mask would silently truncate (kept windows returning as zeros).
    Callers that bucket per batch (:class:`FPCAPipeline`) recompute
    ``m_bucket`` from each mask's kept count, which upholds this by
    construction; anyone reusing one executable across masks must route
    busier masks to a bigger bucket themselves.
    """
    adc = adc or ADCConfig()
    enc = enc or WeightEncoding()
    if impl not in ("pallas", "basis"):
        raise ValueError(f"unknown impl {impl!r}")
    if transfer not in ("f32", "int8"):
        raise ValueError(f"unknown transfer {transfer!r}")
    if transfer != "f32" and impl != "basis":
        raise ValueError(
            f"transfer={transfer!r} is only lowered by the basis impl "
            f"(got impl={impl!r})"
        )
    frozen = freeze_model(model)

    if m_bucket is None:

        @jax.jit
        def run(images: jax.Array, kernel: jax.Array, bn_offset: jax.Array) -> jax.Array:
            return _fpca_conv_impl(
                images, kernel, bn_offset,
                frozen=frozen, spec=spec, adc=adc, enc=enc,
                block_m=block_m, block_c=block_c, interpret=interpret, impl=impl,
                transfer=transfer,
            )

    else:

        @jax.jit
        def run(
            images: jax.Array, kernel: jax.Array, bn_offset: jax.Array,
            window_mask: jax.Array,
        ) -> jax.Array:
            return _fpca_conv_impl(
                images, kernel, bn_offset, window_mask,
                frozen=frozen, spec=spec, adc=adc, enc=enc,
                block_m=block_m, block_c=block_c, interpret=interpret, impl=impl,
                m_bucket=m_bucket, transfer=transfer,
            )

    return run


def fpca_conv(
    images: jax.Array,
    kernel: jax.Array,
    model: BucketCurvefitModel,
    *,
    spec: FPCASpec,
    adc: ADCConfig | None = None,
    enc: WeightEncoding | None = None,
    bn_offset: jax.Array | None = None,
    block_m: int = 256,
    block_c: int = 128,
    interpret: bool | None = None,
    impl: str = "pallas",
    window_mask: jax.Array | np.ndarray | None = None,
    m_bucket: int | None = None,
) -> jax.Array:
    """FPCA frontend activations for a batch of images.

    Args:
      images: ``(B, H, W, c_i)`` float in [0, 1].
      kernel: ``(c_o, k, k, c_i)`` float weights.
      model:  fitted :class:`BucketCurvefitModel` for ``spec.n_active_pixels``.
      impl:   ``"pallas"`` (TPU kernel; interpret-mode elsewhere) or
              ``"basis"`` (same math lowered through XLA — fast on CPU).
      window_mask: optional ``(B, h_o, w_o)`` (or flat) keep mask — kept
              windows are compacted into a static row bucket so skipped
              windows cost no compute; skipped slots return exact zeros.
      m_bucket: static bucket size for the compacted window list; defaults
              to :func:`window_bucket` of the mask's kept count (requires a
              concrete mask).

    Returns:
      SS-ADC counts, ``(B, h_o, w_o, c_o)`` float32 (integer-valued).
    """
    adc = adc or ADCConfig()
    enc = enc or WeightEncoding()
    if impl not in ("pallas", "basis"):
        raise ValueError(f"unknown impl {impl!r}")
    c_o = kernel.shape[0]
    if bn_offset is None:
        bn_offset = jnp.zeros((c_o,), jnp.float32)
    if window_mask is not None:
        # sizing the bucket (m_bucket=None) and checking an undersized one
        # need the concrete kept count; with an explicit full-size m_bucket
        # the mask stays un-materialised (trace-safe, as before the
        # zero-keep short-circuit existed)
        n_keep = (
            int(np.count_nonzero(np.asarray(window_mask)))
            if m_bucket is None or m_bucket < int(np.size(window_mask))
            else None
        )
        if n_keep == 0:
            # all-skipped frame: the output is exact zeros by contract, so
            # short-circuit without any kernel launch (an idle camera tick
            # costs nothing on-device, matching the sensor's gated RS/SW
            # lines never firing)
            h_o, w_o = output_dims(spec)
            return jnp.zeros((images.shape[0], h_o, w_o, c_o), jnp.float32)
        window_mask = jnp.asarray(window_mask)
        if m_bucket is None:
            m_bucket = window_bucket(n_keep, int(window_mask.size))
        elif n_keep is not None and n_keep > m_bucket:
            raise ValueError(
                f"mask keeps {n_keep} windows > m_bucket {m_bucket}; the "
                "fixed-size gather would silently drop kept windows"
            )
    return _fpca_conv_jit(
        images,
        kernel,
        bn_offset,
        window_mask,
        frozen=freeze_model(model),
        spec=spec,
        adc=adc,
        enc=enc,
        block_m=block_m,
        block_c=block_c,
        interpret=interpret,
        impl=impl,
        m_bucket=m_bucket,
    )
