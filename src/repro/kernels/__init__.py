"""Pallas TPU kernels (+ jnp oracles) for the framework's compute hot-spots:

* ``fpca_conv``       — the paper's analog in-pixel convolution as a
                        basis-expanded matmul bank (primary contribution);
* ``flash_attention`` — tiled online-softmax attention (train/prefill);
* ``ssd``             — Mamba2 SSD intra-chunk contraction.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated in interpret mode on CPU against their ``ref.py`` oracles.
"""
