"""Backend-dispatching wrapper: Pallas kernel on TPU, pure-JAX custom_vjp
flash (repro.models.attention.attend_blockwise) elsewhere.

Training on TPU pairs the forward kernel with
``bwd_kernel.flash_attention_bwd_pallas`` (recompute-based, no O(S^2)
residuals) via custom_vjp; on CPU both fall back to the pure-JAX custom_vjp
flash path, which is also their oracle.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.models.attention import attend_blockwise

__all__ = ["flash_attention"]


def flash_attention(
    q, k, v, *, causal: bool = True, window: int | None = None,
    block_q: int = 512, block_k: int = 512, force_pallas: bool = False,
):
    if force_pallas or jax.default_backend() == "tpu":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window,
            block_q=block_q, block_k=block_k,
        )
    return attend_blockwise(q, k, v, causal=causal, window=window, block_k=block_k)
