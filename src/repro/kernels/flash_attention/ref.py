"""Pure-jnp oracle for the flash_attention kernel: plain masked softmax
attention (independently tested in tests/test_arch_smoke via the models)."""

from repro.models.attention import attend_full as flash_attention_ref

__all__ = ["flash_attention_ref"]
