"""Pallas TPU flash-attention backward kernels.

Two kernels, mirroring the recompute-based backward of the pure-JAX
custom_vjp (`repro.models.attention._flash_bwd`):

* ``dq`` kernel  — grid (B, H, nQ, nK): the trailing axis iterates KV blocks
  sequentially, accumulating the query-block gradient in VMEM scratch;
* ``dkdv`` kernel — grid (B, H, nK, nQ): the trailing axis iterates Q blocks,
  accumulating the key/value-block gradients.  GQA: gradients are produced
  per *query* head and group-summed to KV heads outside (a cheap reduce).

Both recompute the probabilities from (q, k, lse) — no O(S^2) residuals, the
flash property.  ``delta = rowsum(dO * O)`` is precomputed outside
(elementwise).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_bwd_pallas"]

_NEG_INF = -1e30


def _masked_p(q, k, lse, q_start, k_start, bq, bk, seq_q, seq_k, causal, window, scale):
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    pos_q = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    pos_k = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (pos_q < seq_q) & (pos_k < seq_k)
    if causal:
        mask &= pos_q >= pos_k
    if window is not None:
        mask &= pos_q - pos_k < window
    s = jnp.where(mask, s, _NEG_INF)
    return jnp.exp(s - lse)


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, acc_scr,
    *, scale, block_q, block_k, seq_q, seq_k, causal, window,
):
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = pl.program_id(2) * block_q
    k_start = ki * block_k
    live = True
    if causal:
        live = q_start + block_q - 1 >= k_start
    if window is not None:
        live = jnp.logical_and(live, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]                                   # (bq, 1)
        delta = delta_ref[0, 0]                               # (bq, 1)
        p = _masked_p(q, k, lse, q_start, k_start, block_q, block_k,
                      seq_q, seq_k, causal, window, scale)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        acc_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ki == nk - 1)
    def _finalize():
        dq_ref[0, 0] = acc_scr[...].astype(dq_ref.dtype)


def _dkdv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_scr, dv_scr,
    *, scale, block_q, block_k, seq_q, seq_k, causal, window,
):
    qi = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start = qi * block_q
    k_start = pl.program_id(2) * block_k
    live = True
    if causal:
        live = q_start + block_q - 1 >= k_start
    if window is not None:
        live = jnp.logical_and(live, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        p = _masked_p(q, k, lse, q_start, k_start, block_q, block_k,
                      seq_q, seq_k, causal, window, scale)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(qi == nq - 1)
    def _finalize():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    out: jax.Array,
    lse: jax.Array,
    g: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Backward pass. Layouts match the forward wrapper:
    q/out/g (B, Sq, H, D), k/v (B, Sk, KV, D), lse (B, KV, G, Sq).

    Returns (dq, dk, dv) in the same layouts.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    group = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    scale = D ** -0.5

    def to_bhsd(x, s, blocks, blk):
        return jnp.pad(x, ((0, 0), (0, blocks * blk - s), (0, 0), (0, 0))).transpose(0, 2, 1, 3)

    qp = to_bhsd(q, Sq, nq, bq)
    op = to_bhsd(out, Sq, nq, bq)
    gp = to_bhsd(g, Sq, nq, bq)
    kp = to_bhsd(k, Sk, nk, bk)
    vp = to_bhsd(v, Sk, nk, bk)
    lse_p = jnp.pad(
        lse.reshape(B, H, Sq), ((0, 0), (0, 0), (0, nq * bq - Sq)),
        constant_values=0.0,
    )[..., None]                                              # (B, H, Sqp, 1)
    delta = jnp.einsum("bhsd,bhsd->bhs", op.astype(jnp.float32), gp.astype(jnp.float32))
    delta = delta[..., None]                                  # (B, H, Sqp, 1)

    common = dict(scale=scale, block_q=bq, block_k=bk, seq_q=Sq, seq_k=Sk,
                  causal=causal, window=window)
    q_spec = pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0))
    k_spec = pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // group, ki, 0))
    lse_spec = pl.BlockSpec((1, 1, bq, 1), lambda b, h, qi, ki: (b, h, qi, 0))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(B, H, nq, nk),
        in_specs=[q_spec, k_spec, k_spec, q_spec, lse_spec, lse_spec],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, gp, lse_p, delta)

    # dk/dv per query head, then group-summed to KV heads
    q_spec2 = pl.BlockSpec((1, 1, bq, D), lambda b, h, ki, qi: (b, h, qi, 0))
    k_spec2 = pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, qi: (b, h // group, ki, 0))
    lse_spec2 = pl.BlockSpec((1, 1, bq, 1), lambda b, h, ki, qi: (b, h, qi, 0))
    out_spec2 = pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, qi: (b, h, ki, 0))
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkdv_kernel, **common),
        grid=(B, H, nk, nq),
        in_specs=[q_spec2, k_spec2, k_spec2, q_spec2, lse_spec2, lse_spec2],
        out_specs=[out_spec2, out_spec2],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nk * bk, D), k.dtype),
            jax.ShapeDtypeStruct((B, H, nk * bk, D), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, D), jnp.float32),
            pltpu.VMEM((bk, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp, gp, lse_p, delta)

    dq = dq.transpose(0, 2, 1, 3)[:, :Sq]
    dk = dk_h.reshape(B, KV, group, nk * bk, D).sum(axis=2).transpose(0, 2, 1, 3)[:, :Sk]
    dv = dv_h.reshape(B, KV, group, nk * bk, D).sum(axis=2).transpose(0, 2, 1, 3)[:, :Sk]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)
