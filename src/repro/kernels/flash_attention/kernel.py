"""Pallas TPU flash-attention (forward) kernel.

Grid ``(B, H, nQ, nK)``; the trailing grid axis iterates KV blocks
sequentially per TPU core, so the online-softmax running state (m, l, acc)
lives in VMEM scratch and is carried across ``ki`` steps — the canonical
TPU flash pattern.  GQA is expressed in the k/v BlockSpec index maps
(``h -> h * KV // H``), so no KV replication is materialised.

Causal + sliding-window masking happens on 2-D iota position grids; fully
masked (q-block, k-block) pairs are skipped with ``pl.when`` (this is the
block-skipping that the pure-JAX path cannot express — on real hardware it
halves causal-attention work; see EXPERIMENTS.md §Perf).

VMEM per program (bq=bk=512, D=128, f32 scratch): q/k/v tiles 3 x 256 KiB +
acc 256 KiB + m/l — ~1 MiB, far under budget; block sizes are exposed as
tuning knobs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
    causal: bool,
    window: int | None,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # block-level skip: causal => no work if the whole k block is in the
    # future; window => no work if the whole k block is out of the window
    live = True
    if causal:
        live = q_start + block_q - 1 >= k_start
    if window is not None:
        live = jnp.logical_and(live, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                          # (bq, bk)
        pos_q = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        pos_k = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = (pos_q < seq_q) & (pos_k < seq_k)
        if causal:
            mask &= pos_q >= pos_k
        if window is not None:
            mask &= pos_q - pos_k < window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention forward. q (B,Sq,H,D), k/v (B,Sk,KV,D) -> (B,Sq,H,D).

    GQA handled via index maps; H must be a multiple of KV.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    qp = jnp.pad(q, ((0, 0), (0, nq * bq - Sq), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kp = jnp.pad(k, ((0, 0), (0, nk * bk - Sk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vp = jnp.pad(v, ((0, 0), (0, nk * bk - Sk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel,
        scale=D ** -0.5,
        block_q=bq,
        block_k=bk,
        seq_q=Sq,
        seq_k=Sk,
        causal=causal,
        window=window,
    )
    group = H // KV
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.transpose(0, 2, 1, 3)[:, :Sq]
