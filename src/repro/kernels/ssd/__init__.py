from repro.kernels.ssd.kernel import ssd_intra_chunk_pallas
from repro.kernels.ssd.ops import ssd_chunked_pallas
from repro.kernels.ssd.ref import ssd_intra_chunk_ref

__all__ = ["ssd_chunked_pallas", "ssd_intra_chunk_pallas", "ssd_intra_chunk_ref"]
