"""Full chunked SSD with the Pallas intra-chunk kernel + JAX inter-chunk
scan; drop-in equivalent of repro.models.ssm.ssd_chunked."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_intra_chunk_pallas

__all__ = ["ssd_chunked_pallas"]


def ssd_chunked_pallas(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 128,
    initial_state: jax.Array | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Same contract as repro.models.ssm.ssd_chunked (see its docstring)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(chunk, l)
    nc = -(-l // q)
    pad = nc * q - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xc = x.reshape(b, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, q, g, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, q, g, n).astype(jnp.float32)
    rep = h // g
    Bh = jnp.repeat(Bc, rep, axis=3) if g > 1 else jnp.broadcast_to(Bc, (b, nc, q, h, n))
    Ch = jnp.repeat(Cc, rep, axis=3) if g > 1 else jnp.broadcast_to(Cc, (b, nc, q, h, n))
    logd = dtc * A.astype(jnp.float32)
    cum = jnp.cumsum(logd, axis=2)
    xbar = xc * dtc[..., None]

    y_intra, states_np = ssd_intra_chunk_pallas(xbar, Bh, Ch, cum, interpret=interpret)
    states = states_np.transpose(0, 1, 2, 4, 3)                 # -> (b,nc,h,p,n)
    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def body(s, inp):
        st, dec = inp
        return dec[:, :, None, None] * s + st, s

    s0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    final_state, prev_states = jax.lax.scan(
        body, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)
    y_inter = jnp.einsum("bcihn,bchpn,bcih->bcihp", Ch, prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, nc * q, h, p)[:, :l]
    return y.astype(x.dtype), final_state
