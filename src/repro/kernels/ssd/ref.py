"""Pure-jnp oracle for the ssd kernel: the independently-tested intra-chunk
math from the model code."""

from repro.models.ssm import ssd_intra_chunk as ssd_intra_chunk_ref

__all__ = ["ssd_intra_chunk_ref"]
