"""Pallas TPU kernel for the Mamba2 SSD intra-chunk computation.

The SSD algorithm's compute hot-spot is the quadratic-within-chunk piece:
per (batch, chunk, head) it is three dense contractions —

    cb    = C  @ B^T                (Q x Q   via MXU)
    y     = (cb * L) @ xbar         (Q x P   via MXU)
    state = (B * decay)^T @ xbar    (N x P   via MXU)

with L the segment-sum decay mask.  The inter-chunk state recurrence is a
tiny sequential scan and stays in JAX (ops.py).

Grid ``(B * nc, H)``: one program owns one (chunk, head) tile; all operands
fit VMEM comfortably (Q=128, N<=128, P<=64: < 200 KiB/program).  Head-dim
tiles are MXU-aligned by zero-padding P and N to 128 on the host.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_intra_chunk_pallas"]


def _ssd_kernel(xbar_ref, b_ref, c_ref, cum_ref, y_ref, state_ref, *, q: int):
    xbar = xbar_ref[0, 0].astype(jnp.float32)     # (Q, P)
    B = b_ref[0, 0].astype(jnp.float32)           # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)           # (Q, N)
    cum = cum_ref[0, 0].astype(jnp.float32)       # (Q, 1)

    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)      # (Q, Q)
    seg = cum - cum.T                                                  # (Q, Q) cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.exp(jnp.where(ii >= jj, seg, -1e30))
    y_ref[0, 0] = jax.lax.dot_general(
        cb * L, xbar, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1:] - cum)                             # (Q, 1)
    state = jax.lax.dot_general(
        B * decay_to_end, xbar, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                                  # (N, P)
    state_ref[0, 0] = state.astype(state_ref.dtype)


def ssd_intra_chunk_pallas(
    xbar: jax.Array,
    Bh: jax.Array,
    Ch: jax.Array,
    cum: jax.Array,
    *,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD. xbar (b,nc,Q,H,P), Bh/Ch (b,nc,Q,H,N), cum (b,nc,Q,H).

    Returns (y_intra (b,nc,Q,H,P), states (b,nc,H,N,P)) — note states come
    back (N, P)-major; ops.py transposes to the model's (P, N) convention.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, nc, q, h, p = xbar.shape
    n = Bh.shape[-1]
    # layout: fold (b, nc) and move H before chunk dims for clean tiling
    xb = xbar.reshape(b * nc, q, h, p).transpose(0, 2, 1, 3)    # (bc, H, Q, P)
    Bb = Bh.reshape(b * nc, q, h, n).transpose(0, 2, 1, 3)
    Cb = Ch.reshape(b * nc, q, h, n).transpose(0, 2, 1, 3)
    cumb = cum.reshape(b * nc, q, h).transpose(0, 2, 1)[..., None]  # (bc, H, Q, 1)

    kernel = functools.partial(_ssd_kernel, q=q)
    y, states = pl.pallas_call(
        kernel,
        grid=(b * nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * nc, h, q, p), jnp.float32),
            jax.ShapeDtypeStruct((b * nc, h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(xb, Bb, Cb, cumb)
    y_out = y.transpose(0, 2, 1, 3).reshape(b, nc, q, h, p)
    states_out = states.reshape(b, nc, h, n, p)
    return y_out, states_out
